"""Reproduce the paper's projection study end-to-end: scale the VLA family
7B -> 100B (scaling laws), price each on every Table-1 system with the XPU
simulator, and print the Figure-3 control-frequency matrix plus the
bottleneck analysis the paper's conclusion rests on.

    PYTHONPATH=src python examples/project_hardware.py
"""
from repro.core.hardware import TABLE1, get_hardware
from repro.core.scaling import scaling_sweep
from repro.core.xpu_sim import simulate_vla

SIZES = (7e9, 30e9, 100e9)


def main():
    cfgs = scaling_sweep(SIZES)
    print(f"{'system':16s}" + "".join(f"{s/1e9:>9.0f}B" for s in SIZES)
          + "   (control frequency, Hz)")
    for hw_name in TABLE1:
        hw = get_hardware(hw_name)
        row = [simulate_vla(c, hw).control_freq_hz for c in cfgs]
        print(f"{hw_name:16s}" + "".join(f"{f:9.3f}" for f in row))
    print("\nbottleneck decomposition (100B on thor+pim):")
    r = simulate_vla(cfgs[-1], get_hardware("thor+pim"))
    for ph in r.phases:
        print(f"  {ph.name:20s} {ph.time():8.3f}s  bound={ph.bound} "
              f"(memory fraction {ph.memory_fraction:.2f})")
    print(f"  e2e {r.e2e:.2f}s -> {r.control_freq_hz:.3f} Hz "
          f"(target: 10-20 Hz) — memory scaling alone is insufficient.")


if __name__ == "__main__":
    main()
