"""End-to-end driver: train a ~100M-class VLA (vision tower + LM backbone +
discrete action tokens) on synthetic episodes for a few hundred steps, with
checkpointing and a mid-run injected failure to exercise fault recovery.

    PYTHONPATH=src python examples/train_vla.py [--steps 300]
"""
import argparse
import dataclasses
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import Prefetcher, vla_batches
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.checkpoint import ResilientLoop, StepFailure, latest_step
from repro.training import (AdamWConfig, TrainConfig, init_train_state,
                            make_train_step)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=8)
    args = p.parse_args()

    # ~100M-class VLA: the molmoact architecture at a width that trains on CPU
    base = get_config("molmoact-7b")
    cfg = dataclasses.replace(
        base, name="vla-100m", num_layers=4, d_model=256, num_heads=8,
        num_kv_heads=4, head_dim=32, d_ff=1024, vocab_size=2048,
        n_prompt_tokens=8, n_cot_tokens=16,
        vision=dataclasses.replace(base.vision, num_layers=2, d_model=128,
                                   num_heads=4, d_ff=512, num_tokens=16,
                                   embed_dim=64),
        action=dataclasses.replace(base.action, num_action_tokens=8))
    n = cfg.param_counts()["total"]
    print(f"training {cfg.name}: {n/1e6:.1f}M params")

    opts = ModelOptions(remat=False)
    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=20,
                                       total_steps=args.steps))
    params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    state = {"params": params, "opt": init_train_state(cfg, tcfg, params)}
    step_fn = jax.jit(make_train_step(cfg, opts, tcfg))
    # unbounded stream: failure-replayed steps consume extra batches
    data = iter(Prefetcher(vla_batches(cfg, args.batch, steps=None)))

    fails = {args.steps // 2}  # inject one failure mid-run

    def fault_hook(s):
        if s in fails:
            fails.discard(s)
            raise StepFailure(f"injected@{s}")

    losses = []
    t0 = time.time()

    def one(state, s, it):
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        p2, o2, m = step_fn(state["params"], state["opt"], batch)
        losses.append(float(m["loss"]))
        if s % 25 == 0:
            print(f"step {s:4d} loss {losses[-1]:.4f} "
                  f"({time.time()-t0:.0f}s)")
        return {"params": p2, "opt": o2}

    with tempfile.TemporaryDirectory() as ck:
        loop = ResilientLoop(one, ck, save_every=50, fault_hook=fault_hook,
                             async_save=True)
        state, _ = loop.run(state, 0, args.steps, data)
        print(f"recovered from {loop.restores} injected failure(s); "
              f"latest checkpoint step {latest_step(ck)}")
    print(f"loss: {losses[0]:.4f} -> {losses[-1]:.4f} "
          f"({'improved' if losses[-1] < losses[0] else 'NOT improved'})")


if __name__ == "__main__":
    main()
