"""Serve a small model with continuously-batched requests (vLLM-style slots,
per-slot cache positions) through the fused device-resident decode loop, and
report the phase latency decomposition plus the host-sync contract — the
paper's measurement, taken on our own serving engine.

    PYTHONPATH=src python examples/serve_batched.py [--reference]
"""
import argparse

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.serving import Request, ServingEngine


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--reference", action="store_true",
                   help="per-token reference path (one host sync per token)")
    p.add_argument("--tick-tokens", type=int, default=8)
    p.add_argument("--paged", action="store_true",
                   help="paged KV cache with prefix sharing instead of "
                        "dense per-slot buffers")
    p.add_argument("--page-size", type=int, default=8,
                   help="tokens per KV page (small default so the 12-token "
                        "demo prompts span a full, shareable page)")
    p.add_argument("--kv-dtype", default="bf16",
                   choices=["bf16", "int8", "fp8"],
                   help="paged pool storage (int8/fp8 = quantized pages "
                        "with per-page scales; needs --paged)")
    p.add_argument("--pallas", action="store_true",
                   help="route decode through the flash-decode Pallas "
                        "kernels (interpret mode on CPU: slow, real path)")
    p.add_argument("--chunked-prefill", action="store_true",
                   help="token-budget scheduler: chunked prefill packed "
                        "between decode ticks, prefix hits skip compute")
    p.add_argument("--chunk-size", type=int, default=8,
                   help="prefill chunk tokens (multiple of --page-size "
                        "when --paged)")
    p.add_argument("--token-budget", type=int, default=24,
                   help="tokens one tick may spend (decode + chunks)")
    p.add_argument("--prefill-band", type=int, default=32,
                   help="key-block size of the banded prefill attention "
                        "core (prefill key work ~ live prefix, not max_seq)")
    p.add_argument("--spec-decode", action="store_true",
                   help="self-speculative decode: draft K tokens with a "
                        "truncated/quantized pass of the same model, verify "
                        "them in one banded chunk (greedy only)")
    p.add_argument("--spec-k", type=int, default=4,
                   help="speculation depth (needs --spec-decode)")
    p.add_argument("--draft-layers", type=int, default=0,
                   help="draft decoder layers (0 = half the stack)")
    p.add_argument("--draft-quant", default="none",
                   choices=["none", "int8", "fp8"],
                   help="fake-quantize the draft pass's weights")
    args = p.parse_args(argv)

    cfg = get_config("qwen1.5-0.5b").reduced()
    opts = ModelOptions(remat=False, use_pallas=args.pallas,
                        prefill_band=args.prefill_band)
    params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    eng = ServingEngine(cfg, opts, params, n_slots=4, max_seq=96, eos=-1,
                        fused=not args.reference,
                        tick_tokens=args.tick_tokens,
                        paged=args.paged, page_size=args.page_size,
                        kv_dtype=args.kv_dtype,
                        chunked_prefill=args.chunked_prefill,
                        chunk_size=args.chunk_size,
                        token_budget=args.token_budget,
                        spec_decode=args.spec_decode, spec_k=args.spec_k,
                        draft_layers=args.draft_layers or None,
                        draft_quant=(None if args.draft_quant == "none"
                                     else args.draft_quant))

    rng = np.random.default_rng(0)
    shared_prompt = rng.integers(0, cfg.vocab_size, 12, dtype=np.int32)
    for i in range(12):
        # every third request repeats the same observation -> prefix hits
        prompt = (shared_prompt.copy() if args.paged and i % 3 == 0 else
                  rng.integers(0, cfg.vocab_size, 12, dtype=np.int32))
        eng.submit(Request(uid=i, prompt=prompt,
                           max_tokens=int(rng.integers(6, 14))))
    done = eng.run()

    st = eng.stats
    toks = sum(len(r.out_tokens) for r in done)
    span = max(r.t_done for r in done) - min(r.t_submit for r in done)
    mode = "reference" if args.reference else "fused"
    print(f"{len(done)} requests, {toks} tokens, {toks/span:.1f} tok/s "
          f"aggregate with continuous batching ({mode} decode path)")
    contract = (f"host-sync contract: {st.decode_syncs} decode syncs for "
                f"{st.tokens_decoded} decoded tokens over "
                f"{st.device_steps} device steps")
    if not args.reference:
        contract += f" (reference path would pay {st.device_steps})"
    print(contract)
    ph = st.phase_report()
    print(f"engine phases: vision {ph['vision']:.3f}s | "
          f"prefill {ph['prefill']:.3f}s | decode {ph['decode']:.3f}s")
    if "prefill_key_lane_ratio" in ph:
        print(f"banded prefill (band {args.prefill_band}): key-lane ratio "
              f"{ph['prefill_key_lane_ratio']:.3f} vs the full max_seq view")
    if args.paged:
        print(f"paged KV pool ({args.kv_dtype}): pages_hwm {st.pages_hwm} | "
              f"cache_bytes_hwm {st.cache_bytes_hwm} | "
              f"prefix_hits {st.prefix_hits}")
    if args.chunked_prefill:
        ph = st.phase_report()
        print(f"scheduler: chunk {args.chunk_size} / budget "
              f"{args.token_budget} | prefill_tokens {st.prefill_tokens} "
              f"(+{st.prefill_skipped} skipped via prefix cache) | "
              f"ttft mean {np.mean(st.ttft_s):.3f}s | "
              f"decode tick p50/p99 "
              f"{ph.get('decode_tick_p50', 0.0) * 1e3:.1f}/"
              f"{ph.get('decode_tick_p99', 0.0) * 1e3:.1f} ms")
    if args.spec_decode:
        print(f"speculative decode (K {args.spec_k}, draft "
              f"{eng.draft_blocks} blocks, quant {args.draft_quant}): "
              f"{ph.get('spec_accept_per_pass', 0.0):.2f} tokens per "
              f"full-model pass | accept hist "
              f"{ph.get('spec_accept_hist', [])} | draft cost "
              f"{ph.get('spec_draft_frac', 0.0):.2f} of total passes")
    print("per-request phases (queue+prefill | decode):")
    for r in sorted(done, key=lambda r: r.uid)[:6]:
        print(f"  req {r.uid:2d}: {r.t_prefill - r.t_submit:6.3f}s | "
              f"{r.t_done - r.t_prefill:6.3f}s  ({len(r.out_tokens)} tok)")


if __name__ == "__main__":
    main()
