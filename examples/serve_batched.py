"""Serve a small model with continuously-batched requests (vLLM-style slots,
per-slot cache positions) and report the phase latency decomposition per
request — the paper's measurement, taken on our own serving engine.

    PYTHONPATH=src python examples/serve_batched.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.serving import Request, ServingEngine


def main():
    cfg = get_config("qwen1.5-0.5b").reduced()
    opts = ModelOptions(remat=False)
    params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    eng = ServingEngine(cfg, opts, params, n_slots=4, max_seq=96, eos=-1)

    rng = np.random.default_rng(0)
    for i in range(12):
        eng.submit(Request(
            uid=i, prompt=rng.integers(0, cfg.vocab_size, 12, dtype=np.int32),
            max_tokens=int(rng.integers(6, 14))))
    done = eng.run()

    toks = sum(len(r.out_tokens) for r in done)
    span = max(r.t_done for r in done) - min(r.t_submit for r in done)
    print(f"{len(done)} requests, {toks} tokens, {toks/span:.1f} tok/s "
          f"aggregate with continuous batching")
    print("per-request phases (queue+prefill | decode):")
    for r in sorted(done, key=lambda r: r.uid)[:6]:
        print(f"  req {r.uid:2d}: {r.t_prefill - r.t_submit:6.3f}s | "
              f"{r.t_done - r.t_prefill:6.3f}s  ({len(r.out_tokens)} tok)")


if __name__ == "__main__":
    main()
