"""Serve a robot fleet through the asyncio front-end: streaming action
chunks, a mid-episode hang-up that frees its KV pages, backpressure on a
burst, and prefix-aware routing of repeat observations across two engine
replicas — the serving story of the paper's action-generation bottleneck,
end to end.

    PYTHONPATH=src python examples/serve_fleet.py [--replicas 2]

The demo walks four scenes (watch the printed narration):

1. stream one robot's action tokens as its replica's ticks produce them
2. cancel a second robot mid-generation and show the pool giving its
   pages back (a disconnected robot must not hold KV capacity)
3. flood the admission queue and catch ``Backpressure.retry_after_s``
4. replay each robot's repeat observation and show prefix-affinity
   routing sending it back to the replica that already holds its context
   KV (``prefix_hits`` climbs on that replica only)
5. (with ``--slo-hz``) submit a realtime control request behind a
   best-effort prefill backlog and show it jumping the queue — the
   engine's per-class deadline scoreboard records the hit
"""
import argparse
import asyncio

import numpy as np
import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.serving import AsyncFrontend, Backpressure, ServingEngine


def make_engine(cfg, opts, params, slo_hz=0.0):
    return ServingEngine(cfg, opts, params, n_slots=2, max_seq=96, eos=-1,
                         fused=True, tick_tokens=4, paged=True, page_size=8,
                         chunked_prefill=True, chunk_size=8,
                         token_budget=24, slo_hz=slo_hz)


async def demo(args):
    cfg = get_config("qwen1.5-0.5b").reduced()
    opts = ModelOptions(remat=False)
    params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    rng = np.random.default_rng(0)
    engines = [make_engine(cfg, opts, params, slo_hz=args.slo_hz)
               for _ in range(args.replicas)]
    contexts = [rng.integers(0, cfg.vocab_size, 24, dtype=np.int32)
                for _ in range(args.replicas * 2)]

    async with AsyncFrontend(engines, queue_limit=3,
                             offload_ticks=True) as fe:
        # -- scene 1: stream an action chunk as it is produced ------------
        stream = await fe.submit(contexts[0], max_tokens=8)
        toks = [tok async for tok in stream]
        print(f"[stream] robot 0 action chunk, token by token: {toks}")

        # -- scene 2: hang up mid-generation, pages come back --------------
        stream = await fe.submit(contexts[1], max_tokens=64)
        got = []
        async for tok in stream:
            got.append(tok)
            if len(got) == 3:
                stream.cancel()
        await fe.drain()
        eng = fe.engines[stream.replica]
        print(f"[cancel] robot 1 hung up after {len(got)}/64 tokens: "
              f"cancelled={stream.cancelled}, replica {stream.replica} "
              f"pages_in_use={eng.pool.pages_in_use} (cached "
              f"{len(eng.pool._cached)} prefix pages retained)")

        # -- scene 3: burst past the admission bound ------------------------
        accepted, rejected, retry = [], 0, 0.0
        for _ in range(args.replicas * 3 + 4):
            try:
                accepted.append(await fe.submit(
                    rng.integers(0, cfg.vocab_size, 16, dtype=np.int32), 6))
            except Backpressure as exc:
                rejected, retry = rejected + 1, exc.retry_after_s
        for s in accepted:
            await s.tokens()
        print(f"[backpressure] burst: {len(accepted)} accepted, {rejected} "
              f"rejected with retry_after={retry * 1e3:.1f}ms "
              f"(queue_limit=3/replica) — all accepted completed")

        # -- scene 4: repeat observations stick to their replica ------------
        warm = [await fe.submit(ctx, 6) for ctx in contexts]
        for s in warm:
            await s.tokens()
        before = [eng.stats.prefix_hits for eng in engines]
        repeats = [await fe.submit(ctx, 6) for ctx in contexts]
        for s in repeats:
            await s.tokens()
        await fe.drain()
        routed = {s.replica for s in repeats}
        print(f"[routing] {len(repeats)} repeat observations routed by "
              f"prefix affinity to replicas {sorted(routed)} "
              f"(routed_prefix={fe.stats.routed_prefix})")
        for i, eng in enumerate(engines):
            print(f"  replica {i}: prefix_hits {before[i]} -> "
                  f"{eng.stats.prefix_hits}, prefill skipped "
                  f"{eng.stats.prefill_skipped} tokens")

        # -- scene 5: a control loop jumps a best-effort backlog ------------
        if args.slo_hz > 0:
            backlog = [await fe.submit(
                rng.integers(0, cfg.vocab_size, 48, dtype=np.int32), 4,
                priority=args.priority) for _ in range(args.replicas)]
            control = await fe.submit(
                contexts[0], max_tokens=4, priority="realtime",
                deadline_s=1.0 / args.slo_hz)
            await control.tokens()
            for s in backlog:
                await s.tokens()
            await fe.drain()
            snap = fe.stats_snapshot()
            score = {k: v for k, v in snap.items()
                     if "deadline" in k or "preemptions" in k}
            print(f"[slo] control request (deadline "
                  f"{1e3 / args.slo_hz:.0f}ms) admitted ahead of "
                  f"{len(backlog)} best-effort prompts; scoreboard: "
                  f"{score}")

    rep = fe.stats.report()
    print(f"[stats] submitted={rep['submitted']} completed={rep['completed']} "
          f"cancelled={rep['cancelled']} rejected={rep['rejected']}; "
          f"client TTFT p50={rep.get('ttft_p50_s', 0.0) * 1e3:.1f}ms")


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--replicas", type=int, default=2,
                   help="engine replicas behind the front-end")
    p.add_argument("--slo-hz", type=float, default=10.0,
                   help="control frequency the engines' SLO controller "
                        "defends in scene 5 (0 skips the scene)")
    p.add_argument("--priority", default="best_effort",
                   choices=["best_effort", "realtime"],
                   help="class of scene 5's backlog requests (the control "
                        "request is always realtime)")
    args = p.parse_args(argv)
    asyncio.run(demo(args))


if __name__ == "__main__":
    main()
