"""Quickstart: build a VLA model, run one phase-decomposed control step,
and price the same workload on the paper's edge platforms.

    PYTHONPATH=src python examples/quickstart.py
"""
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.core.hardware import ORIN, THOR
from repro.core.vla import vla_control_step
from repro.core.xpu_sim import simulate_vla
from repro.models import model as M
from repro.models.layers import ModelOptions


def main():
    # --- 1. a reduced MolmoAct-7B (CPU-friendly), same architecture ------
    cfg = dataclasses.replace(get_config("molmoact-7b").reduced(),
                              n_prompt_tokens=8, n_cot_tokens=16)
    opts = ModelOptions(remat=False)
    params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    print(f"model: {cfg.name} ({cfg.num_layers}L d={cfg.d_model})")

    # --- 2. one full control step: vision -> CoT -> action ---------------
    batch = {
        "tokens": jnp.ones((1, cfg.n_prompt_tokens), jnp.int32),
        "patches": 0.1 * jnp.ones((1, cfg.vision.num_tokens,
                                   cfg.vision.embed_dim)),
    }
    t0 = time.perf_counter()
    out = vla_control_step(cfg, opts, params, batch)
    dt = time.perf_counter() - t0
    print(f"control step: cot={out.cot_tokens.shape} "
          f"actions={out.action_tokens.shape} ({dt:.2f}s on CPU)")

    # --- 3. price the FULL 7B workload on the paper's edge platforms -----
    full = get_config("molmoact-7b")
    for hw in (ORIN, THOR):
        r = simulate_vla(full, hw)
        print(f"{hw.name}: e2e={r.e2e:.2f}s "
              f"({r.control_freq_hz:.3f} Hz, generation "
              f"{r.generation_fraction:.0%} of latency)")


if __name__ == "__main__":
    main()
