"""End-to-end behaviour: the training driver converges, resumes from
checkpoint after injected failure; the serving driver completes; the
roofline report machinery handles real artifacts."""
import json
import os

import numpy as np
import pytest

from repro.launch.train import main as train_main
from repro.launch.serve import main as serve_main
from repro.roofline import collective_bytes, markdown_table, to_terms
from repro.roofline.report import RooflineTerms


def test_train_driver_end_to_end(tmp_path):
    losses = train_main([
        "--arch", "smollm-135m", "--reduced", "--steps", "30",
        "--batch", "8", "--seq", "32", "--lr", "5e-3",
        "--ckpt", str(tmp_path / "ck"), "--save-every", "10",
        "--simulate-failure", "15", "--log-every", "100",
    ])
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))
    # a checkpoint exists and is loadable
    from repro.checkpoint import latest_step
    assert latest_step(str(tmp_path / "ck")) is not None


def test_serve_driver_end_to_end():
    done = serve_main(["--arch", "smollm-135m", "--reduced",
                       "--requests", "5", "--slots", "2",
                       "--prompt-len", "8", "--max-tokens", "4",
                       "--max-seq", "48"])
    assert len(done) == 5
    assert all(len(r.out_tokens) == 4 for r in done)


def test_collective_parser():
    hlo = """
  %ar = f32[1024,16]{1,0} all-reduce(f32[1024,16] %x), replica_groups={}
  %ag.1 = bf16[2048]{0} all-gather(bf16[128] %y), dimensions={0}
  %cp = f32[64,64]{1,0} collective-permute(f32[64,64] %z)
  %t = (f32[16], f32[32]) all-to-all(f32[16] %a, f32[32] %b)
"""
    c = collective_bytes(hlo)
    assert c["all-reduce"] == 1024 * 16 * 4
    assert c["all-gather"] == 2048 * 2
    assert c["collective-permute"] == 64 * 64 * 4
    assert c["all-to-all"] == 16 * 4 + 32 * 4
    assert c["total"] == sum(v for k, v in c.items() if k != "total")


def test_roofline_terms_math():
    t = RooflineTerms(arch="x", shape="train_4k", mesh="single_pod",
                      flops_per_dev=197e12, bytes_per_dev=819e9,
                      coll_bytes_per_dev=50e9, model_flops=197e12 * 256)
    assert t.t_compute == pytest.approx(1.0)
    assert t.t_memory == pytest.approx(1.0)
    assert t.t_collective == pytest.approx(1.0)
    assert t.useful_flops_ratio == pytest.approx(1.0)
    assert t.roofline_fraction == pytest.approx(1.0)
    assert "train_4k" in markdown_table([t])


ART = os.path.join(os.path.dirname(__file__), "..", "artifacts", "dryrun")


@pytest.mark.skipif(not os.path.isdir(ART) or not os.listdir(ART),
                    reason="no dry-run artifacts yet")
def test_dryrun_artifacts_consistent():
    from repro.roofline import load_artifacts
    rows = [r for r in load_artifacts(ART) if "skipped" not in r]
    assert rows, "artifacts dir has no successful cells"
    for r in rows:
        t = to_terms(r)
        assert t.flops_per_dev > 0
        assert t.bytes_per_dev > 0
        assert t.bound_time > 0
