"""Sharded multi-device serving: the GQA-atomic serving-rule table, mesh
factory validation, and bit-equal engine streams on a 1xN CPU mesh.

The e2e cases need >= 2 visible devices; under plain tier-1 (one CPU
device) they skip and only the host-side rule/factory tests run. CI gives
this file 8 fake CPU devices via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; the full
mode x mesh matrix lives in ``benchmarks/sharded_bench.py`` — here we pin
one sharded case and one replication-fallback case.
"""
import jax
import numpy as np
import pytest

from conftest import reduced_params
from repro.configs import get_config
from repro.distributed.sharding import SERVING_RULES, serving_rules
from repro.launch.mesh import make_serving_mesh
from repro.serving import Request, ServingEngine

multi_device = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices (XLA_FLAGS="
           "--xla_force_host_platform_device_count=8)")


# ---------------------------------------------------------------- rules

def test_serving_rules_full_smollm_replicates():
    # smollm-135m ships 9 query / 3 KV heads: neither 2 nor 4 divides 9,
    # so the whole attention block must fall back to replication
    cfg = get_config("smollm-135m")
    assert (cfg.num_heads, cfg.num_kv_heads) == (9, 3)
    for n in (2, 4):
        rules = serving_rules(n, cfg.num_heads, cfg.num_kv_heads)
        assert rules["heads"] is None
        assert rules["kv_heads"] is None
    rules = serving_rules(3, cfg.num_heads, cfg.num_kv_heads)
    assert rules["heads"] == rules["kv_heads"] == "model"


def test_serving_rules_gqa_atomic():
    # query heads divisible but KV heads not (and vice versa) must NOT
    # shard one side alone — the n // G group mapping would pair query
    # heads with the wrong local KV head
    assert serving_rules(4, 16, 9)["heads"] is None
    assert serving_rules(4, 16, 9)["kv_heads"] is None
    assert serving_rules(3, 16, 9)["heads"] is None
    ok = serving_rules(2, 16, 8)
    assert ok["heads"] == ok["kv_heads"] == "model"


def test_serving_rules_reduced_smollm():
    cfg, _ = reduced_params("smollm-135m")
    assert serving_rules(2, cfg.num_heads, cfg.num_kv_heads)["kv_heads"] \
        == "model"
    assert serving_rules(4, cfg.num_heads, cfg.num_kv_heads)["kv_heads"] \
        is None


def test_serving_rules_keep_host_axes_replicated():
    rules = serving_rules(2, 4, 2)
    # batch/sequence axes never shard in serving: slots and pages are
    # host-scheduler currency and every device must hold all of them
    assert rules["batch"] is None
    assert rules["kv_seq"] is None
    assert SERVING_RULES["mlp"] == "model"


# -------------------------------------------------------------- factory

def test_serving_mesh_validates_sizes():
    with pytest.raises(ValueError):
        make_serving_mesh(0)
    with pytest.raises(ValueError, match="xla_force_host_platform"):
        make_serving_mesh(jax.device_count() + 1)


@multi_device
def test_serving_mesh_axis():
    mesh = make_serving_mesh(2)
    assert mesh.axis_names == ("model",)
    assert mesh.shape["model"] == 2


# ------------------------------------------------------------------ e2e

def _stream(cfg, opts, params, mesh=None, **kw):
    eng = ServingEngine(cfg, opts, params, n_slots=2, max_seq=64, eos=-999,
                        fused=True, tick_tokens=4, mesh=mesh, **kw)
    rng = np.random.default_rng(0)
    for i in range(4):
        eng.submit(Request(
            uid=i,
            prompt=rng.integers(2, 200, size=int(rng.integers(5, 20)),
                                dtype=np.int64).astype(np.int32),
            max_tokens=8))
    done = eng.run(max_ticks=500)
    assert len(done) == 4
    return {r.uid: r.out_tokens for r in done}, eng


@multi_device
@pytest.mark.parametrize("kw", [{}, dict(paged=True, page_size=8)],
                         ids=["dense", "paged"])
def test_sharded_streams_bit_equal(opts, kw):
    cfg, params = reduced_params("smollm-135m")
    ref, _ = _stream(cfg, opts, params, **kw)
    got, eng = _stream(cfg, opts, params, mesh=make_serving_mesh(2), **kw)
    assert got == ref
    assert dict(eng.stats.mesh_shape)["model"] == 2


@multi_device
def test_replication_fallback_bit_equal(opts):
    # reduced smollm has 2 KV heads: model=4 cannot shard them, so the
    # engine must serve with heads replicated — still bit-equal, and the
    # honest per-shard accounting reports *full* cache bytes, not total/N
    if jax.device_count() < 4:
        pytest.skip("needs 4 devices")
    cfg, params = reduced_params("smollm-135m")
    kw = dict(paged=True, page_size=8)
    ref, _ = _stream(cfg, opts, params, **kw)
    got, eng = _stream(cfg, opts, params, mesh=make_serving_mesh(4), **kw)
    assert got == ref
    assert eng.stats.cache_bytes_hwm_shard == eng.stats.cache_bytes_hwm


@multi_device
def test_sharded_cache_bytes_halve(opts):
    # 4/2 heads over model=2 shard cleanly: each device owns half of
    # every page, so the per-shard HWM is exactly half the summed figure
    cfg, params = reduced_params("smollm-135m")
    kw = dict(paged=True, page_size=8)
    _, eng = _stream(cfg, opts, params, mesh=make_serving_mesh(2), **kw)
    st = eng.stats
    assert st.cache_bytes_hwm_shard * 2 == st.cache_bytes_hwm
    rep = st.phase_report()
    assert rep["mesh_model"] == 2.0
    assert rep["cache_bytes_hwm_shard"] == float(st.cache_bytes_hwm_shard)
