"""Property-based tests (hypothesis) on the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st

from repro.configs.base import GLOBAL_WINDOW
from repro.core.workload import Op, _expected_experts_hit
from repro.core.xpu_sim import op_time
from repro.core.hardware import ORIN, TPU_V5E, get_hardware
from repro.models import layers as L
from repro.training.compress import quantize_int8, dequantize_int8

SET = dict(max_examples=20, deadline=None)


@given(st.integers(1, 4), st.integers(1, 8), st.integers(0, 3),
       st.integers(1, 4))
@settings(**SET)
def test_attention_rows_sum_to_one(b, s_blocks, kv_ratio, kheads):
    """Softmax weights partition unity => output of attention over constant
    V equals that constant (any mask, any GQA grouping)."""
    S = 16 * s_blocks
    K, G = kheads, kv_ratio + 1
    N = K * G
    key = jax.random.PRNGKey(b * 100 + S)
    q = jax.random.normal(key, (b, S, N, 8))
    k = jax.random.normal(jax.random.PRNGKey(1), (b, S, K, 8))
    v = jnp.ones((b, S, K, 8))
    pos = jnp.arange(S)
    out = L.attention_dense(q, k, v, pos, pos, GLOBAL_WINDOW)
    np.testing.assert_allclose(np.asarray(out), 1.0, atol=1e-5)


@given(st.integers(1, 3), st.integers(1, 4), st.sampled_from([8, 16, 32]))
@settings(**SET)
def test_rope_preserves_norm(b, s, hd):
    key = jax.random.PRNGKey(b + s)
    x = jax.random.normal(key, (b, s, 2, hd))
    pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    y = L.rope(x, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-4)


@given(st.integers(0, 5))
@settings(**SET)
def test_rope_relative_position_invariance(shift):
    """<rope(q,p), rope(k,p')> depends only on p - p'."""
    key = jax.random.PRNGKey(0)
    q = jax.random.normal(key, (1, 1, 1, 16))
    k = jax.random.normal(jax.random.PRNGKey(1), (1, 1, 1, 16))
    def dot(p_q, p_k):
        qq = L.rope(q, jnp.asarray([[p_q]]), 10_000.0)
        kk = L.rope(k, jnp.asarray([[p_k]]), 10_000.0)
        return float(jnp.sum(qq * kk))
    assert dot(3, 1) == pytest.approx(dot(3 + shift, 1 + shift), abs=1e-4)


@given(st.integers(2, 64), st.integers(1, 8),
       st.floats(1.0, 64.0))
@settings(**SET)
def test_expected_experts_monotone(E, k, tokens):
    k = min(k, E)
    h1 = _expected_experts_hit(E, k, tokens)
    h2 = _expected_experts_hit(E, k, tokens * 2)
    assert 0 < h1 <= h2 <= E + 1e-9


@given(st.floats(1e3, 1e15), st.floats(1e3, 1e12))
@settings(**SET)
def test_roofline_time_lower_bounds(flops, bytes_):
    op = Op("x", "gemm", flops, bytes_, 0.0)
    for hw in (ORIN, TPU_V5E, get_hardware("orin+pim")):
        t = op_time(op, hw)
        assert t.t >= t.t_compute and t.t >= t.t_memory
        assert t.t > 0


@given(st.lists(st.floats(-100, 100), min_size=4, max_size=64))
@settings(**SET)
def test_int8_quantization_bounded_error(vals):
    x = jnp.asarray(vals, jnp.float32)
    q, s = quantize_int8(x)
    err = float(jnp.abs(dequantize_int8(q, s) - x).max())
    assert err <= float(s) * 0.5 + 1e-6


@given(st.integers(1, 3), st.sampled_from([32, 64]), st.integers(1, 4))
@settings(max_examples=8, deadline=None)
def test_ssd_chunk_size_invariance(b, S, h):
    """SSD output must not depend on the chunk size."""
    key = jax.random.PRNGKey(b * 7 + S + h)
    ks = jax.random.split(key, 5)
    P, N = 8, 16
    xs = jax.random.normal(ks[0], (b, S, h, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, h)))
    A = jax.random.uniform(ks[2], (h,), minval=0.0, maxval=1.0)
    B_ = 0.3 * jax.random.normal(ks[3], (b, S, 1, N))
    C_ = 0.3 * jax.random.normal(ks[4], (b, S, 1, N))
    y1, s1 = L.ssd_chunked(xs, dt, A, B_, C_, chunk=16)
    y2, s2 = L.ssd_chunked(xs, dt, A, B_, C_, chunk=S)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=2e-4, rtol=2e-3)


@given(chunk=st.integers(1, 19), paged=st.booleans(),
       lens=st.lists(st.integers(1, 40), min_size=1, max_size=3))
@settings(max_examples=5, deadline=None)
def test_chunked_stream_bit_identical_random_chunks(chunk, paged, lens):
    """Greedy streams must be bit-identical between monolithic prefill and
    chunked prefill for *any* chunk size and non-aligned prompt lengths, on
    both cache layouts — the banded chunk core's structural contract
    (blockwise online softmax over a fixed absolute key partition), not a
    {16, 64, full}-specific accident. The body lives in test_scheduler (a
    hypothesis-free module), whose fixed-draw smoke keeps the path covered
    when hypothesis is absent."""
    from test_scheduler import check_chunk_invariance
    check_chunk_invariance(chunk, paged, lens)


@given(chunk_size=st.integers(1, 32), token_budget=st.integers(1, 128),
       n_active=st.integers(0, 8), tick_tokens=st.integers(1, 16),
       totals=st.lists(st.integers(1, 300), min_size=0, max_size=4))
@settings(max_examples=100, deadline=None)
def test_scheduler_budget_conservation(chunk_size, token_budget, n_active,
                                       tick_tokens, totals):
    """plan_tick never over-plans: chunks fit the post-reservation budget,
    the whole tick fits token_budget whenever >= 1 token/slot exists, and
    every chunk is well-formed. Body in test_scheduler (hypothesis-free)."""
    from test_scheduler import check_budget_conservation
    check_budget_conservation(chunk_size, token_budget, n_active,
                              tick_tokens, totals)


@given(token_budget=st.integers(1, 64), n_active=st.integers(0, 12),
       tick_tokens=st.integers(1, 16))
@settings(max_examples=60, deadline=None)
def test_scheduler_decode_floor(token_budget, n_active, tick_tokens):
    from test_scheduler import check_decode_floor
    check_decode_floor(token_budget, n_active, tick_tokens)


@given(specs=st.lists(st.tuples(st.booleans(),
                                st.integers(0, 5).map(float),
                                st.booleans()),
                      min_size=0, max_size=12))
@settings(max_examples=100, deadline=None)
def test_scheduler_class_queue_order(specs):
    """insert_by_class: realtime EDF segment strictly ahead of best-effort,
    FCFS seniority within class for plain arrivals."""
    from test_scheduler import check_insert_by_class
    check_insert_by_class(specs)


@given(fronts=st.lists(st.booleans(), min_size=0, max_size=12))
@settings(max_examples=60, deadline=None)
def test_scheduler_all_best_effort_degeneracy(fronts):
    """No realtime anywhere => class insertion is bit-identical to the
    static append/insert(0) policy."""
    from test_scheduler import check_all_best_effort_degeneracy
    check_all_best_effort_degeneracy(fronts)


@given(specs=st.lists(st.tuples(st.booleans(), st.booleans()),
                      min_size=0, max_size=8),
       exclude=st.integers(-1, 7))
@settings(max_examples=80, deadline=None)
def test_scheduler_eviction_never_selects_realtime(specs, exclude):
    from test_scheduler import check_eviction_victim_class
    check_eviction_victim_class(specs, exclude)


@given(token_budget=st.integers(1, 96), chunk_size=st.integers(1, 32),
       rt_total=st.integers(1, 200), be_total=st.integers(1, 200),
       quota=st.integers(0, 64), need=st.integers(0, 16),
       n_active=st.integers(0, 6), tick_tokens=st.integers(1, 12))
@settings(max_examples=100, deadline=None)
def test_scheduler_slo_quota_and_boost(token_budget, chunk_size, rt_total,
                                       be_total, quota, need, n_active,
                                       tick_tokens):
    """SLO tick semantics: quota caps best-effort chunks only, decode_need
    deepens the reservation up to tick_tokens, and a default SLOTick plans
    bit-identically to slo=None."""
    from test_scheduler import check_slo_quota_and_boost
    check_slo_quota_and_boost(token_budget, chunk_size, rt_total, be_total,
                              quota, need, n_active, tick_tokens)


@given(st.integers(2, 6), st.integers(1, 3))
@settings(max_examples=10, deadline=None)
def test_moe_gate_weights_normalized(e, k):
    """MoE output is a convex combination: constant expert outputs =>
    constant output regardless of routing."""
    k = min(k, e)
    import dataclasses
    from repro.configs import get_config
    from repro.models.layers import ModelOptions, moe
    cfg = dataclasses.replace(get_config("granite-moe-3b-a800m").reduced(),
                              num_experts=e, top_k=k, moe_d_ff=8)
    key = jax.random.PRNGKey(e * 10 + k)
    D = cfg.d_model
    p = {
        "router": jax.random.normal(key, (D, e)),
        "moe_wi": jnp.zeros((e, D, 8)),
        "moe_wg": jnp.zeros((e, D, 8)),
        "moe_wo": jnp.zeros((e, 8, D)),
    }
    x = jax.random.normal(key, (2, 4, D))
    out = moe(p, x, cfg, ModelOptions(moe_capacity_factor=float(e)))
    np.testing.assert_allclose(np.asarray(out), 0.0, atol=1e-6)
