"""Data pipeline: shapes, shard disjointness, prefetcher."""
import numpy as np

from repro.configs import get_config
from repro.data import Prefetcher, lm_batches, vla_batches


def test_lm_batch_shapes():
    cfg = get_config("internvl2-1b").reduced()
    b = next(lm_batches(cfg, 4, 16, steps=1))
    assert b["tokens"].shape == (4, 16)
    assert b["tokens"].dtype == np.int32
    assert b["patches"].shape == (4, cfg.vision.num_tokens,
                                  cfg.vision.embed_dim)
    assert b["tokens"].min() >= 0 and b["tokens"].max() < cfg.vocab_size


def test_shards_are_disjoint():
    cfg = get_config("smollm-135m").reduced()
    a = next(lm_batches(cfg, 8, 16, shard=0, num_shards=2, steps=1))
    b = next(lm_batches(cfg, 8, 16, shard=1, num_shards=2, steps=1))
    assert a["tokens"].shape == (4, 16)
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_determinism():
    cfg = get_config("smollm-135m").reduced()
    a = next(lm_batches(cfg, 4, 8, seed=3, steps=1))
    b = next(lm_batches(cfg, 4, 8, seed=3, steps=1))
    np.testing.assert_array_equal(a["tokens"], b["tokens"])


def test_vla_batches():
    cfg = get_config("molmoact-7b").reduced()
    b = next(vla_batches(cfg, 2, steps=1))
    n = cfg.n_prompt_tokens + cfg.n_cot_tokens + cfg.action.num_action_tokens
    assert b["tokens"].shape == (2, n)
    # action tokens live in the top-of-vocab bins
    assert b["tokens"][:, -cfg.action.num_action_tokens:].min() \
        >= cfg.vocab_size - 256


def test_prefetcher_preserves_order_and_count():
    it = iter([{"x": np.full((1,), i)} for i in range(7)])
    out = list(Prefetcher(it, depth=3))
    assert [int(o["x"][0]) for o in out] == list(range(7))
