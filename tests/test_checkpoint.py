"""Checkpoint store, resilient loop, elastic shrink."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (ResilientLoop, StepFailure, elastic_shrink,
                              latest_step, restore, save)
from repro.launch.mesh import make_elastic_mesh


def _tree(key=0):
    k = jax.random.PRNGKey(key)
    return {"a": {"w": jax.random.normal(k, (8, 4))},
            "b": [jnp.arange(5), jnp.ones((2, 2), jnp.bfloat16)],
            "count": jnp.asarray(7)}


def test_roundtrip(tmp_path):
    t = _tree()
    save(str(tmp_path), 3, t)
    assert latest_step(str(tmp_path)) == 3
    back = restore(str(tmp_path), 3, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_async_save_and_latest(tmp_path):
    t = _tree()
    h = save(str(tmp_path), 1, t, async_=True)
    h.join()
    save(str(tmp_path), 2, t)
    assert latest_step(str(tmp_path)) == 2


def test_atomicity_no_tmp_left(tmp_path):
    save(str(tmp_path), 1, _tree())
    assert not any(d.endswith(".tmp") for d in os.listdir(tmp_path))


def test_resilient_loop_recovers(tmp_path):
    fails = {5: 1, 11: 2}

    def hook(step):
        if fails.get(step, 0) > 0:
            fails[step] -= 1
            raise StepFailure(f"injected@{step}")

    loop = ResilientLoop(lambda st, s: {"x": st["x"] + 1}, str(tmp_path),
                         save_every=3, fault_hook=hook, async_save=False)
    state, end = loop.run({"x": jnp.asarray(0)}, 0, 20)
    assert loop.restores >= 1
    assert int(state["x"]) >= 18  # restored steps re-run


def test_resilient_loop_gives_up(tmp_path):
    def hook(step):
        raise StepFailure("always")
    loop = ResilientLoop(lambda st, s: st, str(tmp_path), save_every=5,
                         fault_hook=hook, max_retries=2, async_save=False)
    with pytest.raises(StepFailure):
        loop.run({"x": jnp.asarray(0)}, 0, 5)


def test_elastic_shrink_single_device():
    """With 1 real device the shrink path still re-places state intact."""
    mesh = make_elastic_mesh(1, 1)
    state = _tree()
    new_state, new_mesh = elastic_shrink(
        state, mesh,
        make_mesh=lambda d: mesh,
        sharding_fn=lambda tree, m: jax.tree.map(lambda x: None, tree),
        lost_nodes=0)
    assert new_mesh is mesh
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(new_state)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))
