"""Async serving front-end: cancellation frees slots + pool pages
(mid-prefill and mid-decode), bounded admission rejects instead of
deadlocking, streamed tokens match the synchronous engine, and the fleet
trace generator replays deterministically per seed."""
import asyncio

import numpy as np
import pytest

from repro.core.workload import fleet_trace
from repro.serving import AsyncFrontend, Backpressure, Request, ServingEngine
from conftest import reduced_params, opts  # noqa: F401  (fixture)

ARCH = "smollm-135m"


def _engine(cfg, opts, params, **kw):
    kw.setdefault("n_slots", 2)
    kw.setdefault("max_seq", 64)
    return ServingEngine(cfg, opts, params, eos=-999, fused=True,
                         tick_tokens=4, **kw)


def _paged_chunked(cfg, opts, params, **kw):
    kw.setdefault("paged", True)
    kw.setdefault("page_size", 8)
    kw.setdefault("chunked_prefill", True)
    kw.setdefault("chunk_size", 16)
    kw.setdefault("token_budget", 16)
    return _engine(cfg, opts, params, **kw)


# ---------------------------------------------------------------------------
# engine-level cancellation (ServingEngine.cancel)
# ---------------------------------------------------------------------------

def test_cancel_mid_prefill_frees_slot_and_pages(opts):
    """Cancelling a request whose prefill is mid-chunk drops the task and
    returns the pool to baseline; the engine keeps serving afterwards."""
    cfg, params = reduced_params(ARCH)
    rng = np.random.default_rng(0)
    eng = _paged_chunked(cfg, opts, params)
    eng.submit(Request(uid=0,
                       prompt=rng.integers(0, cfg.vocab_size, 48,
                                           dtype=np.int32),
                       max_tokens=8))
    eng.step_fused()        # one tick = one 16-token chunk of the 48
    assert eng.scheduler.tasks, "prefill should still be in flight"
    assert eng.pool.pages_in_use > 0
    assert eng.cancel(0) is True
    assert not eng.scheduler.tasks
    assert eng.pool.pages_in_use == 0, \
        "mid-prefill cancel must free every non-cached pool page"
    assert eng.pending == 0
    # engine is still healthy: a fresh request completes normally
    eng.submit(Request(uid=1,
                       prompt=rng.integers(0, cfg.vocab_size, 12,
                                           dtype=np.int32),
                       max_tokens=5))
    done = eng.run(max_ticks=500)
    assert [r.uid for r in done] == [1]
    assert len(done[0].out_tokens) == 5


def test_cancel_mid_decode_frees_slot_and_pages(opts):
    """Cancelling a decoding slot frees its pages within one tick."""
    cfg, params = reduced_params(ARCH)
    rng = np.random.default_rng(1)
    eng = _paged_chunked(cfg, opts, params)
    eng.submit(Request(uid=0,
                       prompt=rng.integers(0, cfg.vocab_size, 16,
                                           dtype=np.int32),
                       max_tokens=40))
    for _ in range(10):
        eng.step_fused()
        if not eng.scheduler.tasks and eng.pending:
            break
    assert eng.pending == 1 and not eng.scheduler.tasks, "should be decoding"
    assert eng.cancel(0) is True
    assert eng.pool.pages_in_use == 0
    assert eng.pending == 0


def test_cancel_queued_and_unknown_uid(opts):
    """A still-queued request cancels without touching the pool; an
    unknown uid reports False."""
    cfg, params = reduced_params(ARCH)
    rng = np.random.default_rng(2)
    eng = _paged_chunked(cfg, opts, params)
    for uid in range(2):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab_size, 8,
                                               dtype=np.int32),
                           max_tokens=4))
    assert eng.cancel(1) is True            # never admitted
    assert eng.cancel(99) is False
    done = eng.run(max_ticks=500)
    assert [r.uid for r in done] == [0]


# ---------------------------------------------------------------------------
# front-end: streaming, cancellation, backpressure
# ---------------------------------------------------------------------------

def test_frontend_streams_bit_equal_to_sync_engine(opts):
    cfg, params = reduced_params(ARCH)
    rng = np.random.default_rng(3)
    reqs = [(rng.integers(0, cfg.vocab_size, l, dtype=np.int32), m)
            for l, m in [(11, 5), (23, 4), (7, 6)]]
    eng = _engine(cfg, opts, params)
    for i, (p, m) in enumerate(reqs):
        eng.submit(Request(uid=i, prompt=p.copy(), max_tokens=m))
    base = {r.uid: r.out_tokens for r in eng.run(max_ticks=500)}

    async def go():
        async with AsyncFrontend([_engine(cfg, opts, params)],
                                 offload_ticks=False) as fe:
            streams = [await fe.submit(p, m) for p, m in reqs]
            return [await s.tokens() for s in streams]

    outs = asyncio.run(go())
    assert outs == [base[i] for i in range(len(reqs))]


def test_frontend_cancel_mid_decode_returns_pool_to_baseline(opts):
    cfg, params = reduced_params(ARCH)
    rng = np.random.default_rng(4)

    async def go():
        eng = _paged_chunked(cfg, opts, params)
        async with AsyncFrontend([eng], offload_ticks=False) as fe:
            stream = await fe.submit(
                rng.integers(0, cfg.vocab_size, 16, dtype=np.int32), 40)
            got = []
            async for tok in stream:
                got.append(tok)
                if len(got) == 3:
                    stream.cancel()
            await fe.drain()
            return eng, stream, got

    eng, stream, got = asyncio.run(go())
    assert stream.cancelled is True
    assert 3 <= len(got) < 40, "stream should be truncated by the cancel"
    assert eng.pool.pages_in_use == 0
    assert eng.pending == 0


def test_frontend_cancel_before_engine_submission(opts):
    """Cancelling a stream that is still staged never reaches the engine."""
    cfg, params = reduced_params(ARCH)
    rng = np.random.default_rng(5)

    async def go():
        eng = _paged_chunked(cfg, opts, params)
        async with AsyncFrontend([eng], offload_ticks=False) as fe:
            stream = await fe.submit(
                rng.integers(0, cfg.vocab_size, 16, dtype=np.int32), 8)
            stream.cancel()     # driver has not drained the staging deque
            toks = await stream.tokens()
            await fe.drain()
            return eng, stream, toks, fe

    eng, stream, toks, fe = asyncio.run(go())
    assert stream.cancelled is True and toks == []
    assert fe.stats.cancelled == 1 and fe.stats.completed == 0
    assert eng.stats.ticks == 0 or eng.pool.pages_in_use == 0


def test_frontend_over_limit_rejects_without_deadlock(opts):
    """Submissions past queue_limit raise Backpressure (with a positive
    retry estimate); every accepted request still completes in full."""
    cfg, params = reduced_params(ARCH)
    rng = np.random.default_rng(6)
    limit = 2

    async def go():
        async with AsyncFrontend([_paged_chunked(cfg, opts, params)],
                                 queue_limit=limit,
                                 offload_ticks=False) as fe:
            accepted, errors = [], []
            for _ in range(limit + 4):
                try:
                    accepted.append(await fe.submit(
                        rng.integers(0, cfg.vocab_size, 12, dtype=np.int32),
                        6))
                except Backpressure as exc:
                    errors.append(exc)
            outs = [await asyncio.wait_for(s.tokens(), timeout=60)
                    for s in accepted]
            await fe.drain()
            return accepted, errors, outs, fe

    accepted, errors, outs, fe = asyncio.run(go())
    assert len(accepted) == limit
    assert len(errors) == 4 and fe.stats.rejected == 4
    assert all(e.retry_after_s > 0 for e in errors)
    assert all(len(o) == 6 for o in outs), "accepted requests must finish"


def test_backpressure_retry_tracks_tick_ewma(opts):
    """The retry-after estimate is driven by the engine's measured per-tick
    EWMA, not a fixed cap: when ticks speed up, the estimate tightens
    proportionally. Set the EWMA directly for determinism (the routing
    math is synchronous, no driver needed)."""
    cfg, params = reduced_params(ARCH)
    rng = np.random.default_rng(8)
    eng = _paged_chunked(cfg, opts, params)
    for uid in range(2):
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab_size, 12,
                                               dtype=np.int32),
                           max_tokens=4))
    fe = AsyncFrontend([eng], queue_limit=2)
    prompt = rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
    eng.stats.tick_ewma_s = 0.5
    with pytest.raises(Backpressure) as slow:
        fe._route(prompt, None)
    eng.stats.tick_ewma_s = 0.05            # ticks sped up 10x
    with pytest.raises(Backpressure) as fast:
        fe._route(prompt, None)
    assert slow.value.retry_after_s == pytest.approx(2 * 0.5)
    assert fast.value.retry_after_s == pytest.approx(2 * 0.05)
    assert fast.value.retry_after_s < slow.value.retry_after_s
    # before the engine has ever ticked, the driver-side estimate holds
    eng.stats.tick_ewma_s = 0.0
    with pytest.raises(Backpressure) as cold:
        fe._route(prompt, None)
    assert cold.value.retry_after_s == \
        pytest.approx(max(1e-3, 2 * fe._tick_ewma[0]))


def test_realtime_reserve_class_admission(opts):
    """With a realtime_reserve, best-effort admits against the reduced
    limit (and its Backpressure names the class) while realtime still
    sees the full queue_limit."""
    cfg, params = reduced_params(ARCH)
    rng = np.random.default_rng(9)
    eng = _paged_chunked(cfg, opts, params)
    fe = AsyncFrontend([eng], queue_limit=3, realtime_reserve=1)
    assert fe.class_limit("realtime") == 3
    assert fe.class_limit("best_effort") == 2
    for uid in range(2):                    # fill the best-effort share
        eng.submit(Request(uid=uid,
                           prompt=rng.integers(0, cfg.vocab_size, 12,
                                               dtype=np.int32),
                           max_tokens=4))
    prompt = rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
    with pytest.raises(Backpressure) as exc:
        fe._route(prompt, None)
    assert exc.value.priority == "best_effort"
    assert fe._route(prompt, None, priority="realtime") == 0
    with pytest.raises(ValueError, match="realtime_reserve"):
        AsyncFrontend([eng], queue_limit=2, realtime_reserve=2)


# ---------------------------------------------------------------------------
# fleet trace generator
# ---------------------------------------------------------------------------

def test_fleet_trace_seeded_replay_deterministic():
    kw = dict(n_robots=5, steps_per_robot=4, control_hz=10.0,
              arrival_rate=3.0, ctx_median=24, ctx_sigma=0.5, ctx_max=48,
              tail=4, action_tokens=8, vocab_size=500)
    a = fleet_trace(seed=7, **kw)
    b = fleet_trace(seed=7, **kw)
    assert len(a) == len(b) == 20
    for x, y in zip(a, b):
        assert (x.t, x.robot, x.step, x.kind, x.max_tokens,
                x.deadline_s) == (y.t, y.robot, y.step, y.kind,
                                  y.max_tokens, y.deadline_s)
        assert np.array_equal(x.prompt, y.prompt)
    c = fleet_trace(seed=8, **kw)
    assert any(not np.array_equal(x.prompt, z.prompt)
               for x, z in zip(a, c)), "different seed, same trace?"


def test_fleet_trace_structure():
    """Arrival order, per-robot prefix sharing, periods, and deadlines."""
    hz, tail = 10.0, 4
    trace = fleet_trace(n_robots=4, steps_per_robot=3, control_hz=hz,
                        ctx_median=24, ctx_max=48, tail=tail, seed=0)
    assert [(-e.t, e.robot, e.step) for e in trace] == sorted(
        [(-e.t, e.robot, e.step) for e in trace], reverse=True)
    by_robot = {}
    for e in trace:
        by_robot.setdefault(e.robot, []).append(e)
    for events in by_robot.values():
        events.sort(key=lambda e: e.step)
        assert events[0].kind == "episode"
        assert events[0].priority == "best_effort"
        assert events[0].deadline_s == pytest.approx(10 / hz)
        ctx = events[0].prompt[:-tail]
        assert len(ctx) >= tail + 1
        for e in events[1:]:
            assert e.kind == "control"
            assert e.priority == "realtime"
            assert e.deadline_s == pytest.approx(1 / hz)
            # repeats share the robot's full context prefix, fresh tail
            assert np.array_equal(e.prompt[:-tail], ctx)
            assert e.t == pytest.approx(events[0].t + e.step / hz)


def test_fleet_trace_validates_args():
    with pytest.raises(ValueError):
        fleet_trace(n_robots=0)
    with pytest.raises(ValueError):
        fleet_trace(control_hz=0.0)
    with pytest.raises(ValueError):
        fleet_trace(arrival_rate=-1.0)
