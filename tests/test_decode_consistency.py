"""Incremental decode must reproduce the full forward pass — the core
serving invariant, checked for every architecture family (attention KV
cache, SSM state cache, cross-attention cache, VLM prefix, MoE with
no-drop capacity)."""
import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs
from repro.models import model as M
from repro.models.layers import ModelOptions
from conftest import reduced_params

TOL = 2e-4


@pytest.mark.parametrize("name", list(list_archs()))
def test_decode_matches_forward(name, key):
    cfg, params = reduced_params(name)
    # no-drop capacity so MoE routing is batch-size independent
    opts = ModelOptions(remat=False, moe_capacity_factor=64.0)
    B, S, extra = 2, 8, 3
    tok = jax.random.randint(key, (B, S + extra), 0, cfg.vocab_size)
    batch = {"tokens": tok[:, :S]}
    n_prefix = 0
    if cfg.encoder is not None:
        batch["frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.encoder.num_tokens, cfg.encoder.embed_dim))
    if cfg.vision is not None:
        batch["patches"] = 0.1 * jax.random.normal(
            key, (B, cfg.vision.num_tokens, cfg.vision.embed_dim))
        n_prefix = cfg.vision.num_tokens

    full = M.forward(cfg, opts, params, {**batch, "tokens": tok})
    logits, caches = M.prefill(cfg, opts, params, batch,
                               max_seq=n_prefix + S + extra + 2,
                               cache_dtype=jnp.float32)
    errs = [float(jnp.abs(logits[:, 0] - full[:, n_prefix + S - 1]).max())]
    for i in range(extra):
        logits, caches = M.decode_step(cfg, opts, params,
                                       tok[:, S + i:S + i + 1], caches,
                                       n_prefix + S + i)
        errs.append(float(jnp.abs(logits[:, 0] - full[:, n_prefix + S + i]).max()))
    assert max(errs) < TOL, f"{name}: decode diverges {errs}"


def test_per_slot_index_decode(key):
    """Per-slot cache indices (continuous batching) must equal running the
    slots independently."""
    cfg, params = reduced_params("qwen1.5-0.5b")
    opts = ModelOptions(remat=False)
    lens = [5, 9]
    B = len(lens)
    toks = [jax.random.randint(jax.random.PRNGKey(i), (1, lens[i]), 0,
                               cfg.vocab_size) for i in range(B)]
    # independent single-stream references
    refs = []
    for t in toks:
        lg, c = M.prefill(cfg, opts, params, {"tokens": t}, 32,
                          cache_dtype=jnp.float32)
        nxt = jnp.argmax(lg[:, -1], -1)[:, None].astype(jnp.int32)
        lg2, _ = M.decode_step(cfg, opts, params, nxt, c, t.shape[1])
        refs.append(lg2[0, 0])
    # batched with per-slot indices
    caches = M.init_caches(cfg, B, 32, jnp.float32)
    first = []
    for s, t in enumerate(toks):
        lg, c1 = M.prefill(cfg, opts, params, {"tokens": t}, 32,
                           cache_dtype=jnp.float32)
        from repro.serving.engine import _scatter_slot
        caches = _scatter_slot(caches, c1, s)
        first.append(jnp.argmax(lg[:, -1], -1)[0])
    tok_b = jnp.asarray(first, jnp.int32)[:, None]
    idx = jnp.asarray(lens, jnp.int32)
    lg, _ = M.decode_step(cfg, opts, params, tok_b, caches, idx)
    for s in range(B):
        err = float(jnp.abs(lg[s, 0] - refs[s]).max())
        assert err < 1e-4, f"slot {s}: {err}"
