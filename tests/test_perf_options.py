"""§Perf optimization options must preserve correctness exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import layers as L
from repro.models import model as M
from repro.models.layers import ModelOptions
from conftest import reduced_params


@pytest.mark.slow
def test_window_cache_ring_matches_full():
    """Ring-buffer KV cache (window_cache) decodes identically to a full
    cache, including past the ring-wrap boundary."""
    cfg, params = reduced_params("gemma3-27b")   # local windows = 32 reduced
    o_full = ModelOptions(remat=False)
    o_ring = ModelOptions(remat=False, window_cache=True)
    B, S0, n = 1, 8, 40
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, S0 + n), 0,
                             cfg.vocab_size)
    lf, cf = M.prefill(cfg, o_full, params, {"tokens": tok[:, :S0]}, 64,
                       cache_dtype=jnp.float32)
    lr, cr = M.prefill(cfg, o_ring, params, {"tokens": tok[:, :S0]}, 64,
                       cache_dtype=jnp.float32)
    errs = [float(jnp.abs(lf - lr).max())]
    for i in range(n):
        lf, cf = M.decode_step(cfg, o_full, params, tok[:, S0+i:S0+i+1],
                               cf, S0 + i)
        lr, cr = M.decode_step(cfg, o_ring, params, tok[:, S0+i:S0+i+1],
                               cr, S0 + i)
        errs.append(float(jnp.abs(lf - lr).max()))
    assert max(errs) < 1e-4, errs


def test_ring_cache_is_smaller():
    from repro.models import stacks
    cfg = get_config("gemma3-27b").reduced()
    full = stacks.cache_template(cfg, 1, 256, opts=ModelOptions())
    ring = stacks.cache_template(cfg, 1, 256,
                                 opts=ModelOptions(window_cache=True))
    sz = lambda t: sum(np.prod(l.shape) for l in jax.tree.leaves(
        t, is_leaf=lambda x: hasattr(x, "axes")))
    assert sz(ring) < 0.5 * sz(full)


def test_causal_pairs_matches_dense():
    key = jax.random.PRNGKey(0)
    B, S, N, K, h = 2, 512, 4, 2, 32
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, N, h))
    k = jax.random.normal(ks[1], (B, S, K, h))
    v = jax.random.normal(ks[2], (B, S, K, h))
    pos = jnp.arange(S)
    for w in (0, 96):
        d = L.attention_dense(q, k, v, pos, pos, w)
        cp = L.attention_flash_ref(q, k, v, pos, pos, w, 128,
                                   causal_pairs=True)
        np.testing.assert_allclose(np.asarray(cp), np.asarray(d),
                                   atol=2e-5, rtol=2e-5)


def test_lm_head_layout_tied_and_untied():
    """[V,D] head layout: logits must equal x @ head.T for both modes."""
    for name in ("arctic-480b", "qwen1.5-0.5b"):   # untied / tied
        cfg, params = reduced_params(name)
        x = jax.random.normal(jax.random.PRNGKey(0), (1, 3, cfg.d_model))
        from repro.models.model import _logits
        lg = _logits(params, x, cfg)
        assert lg.shape == (1, 3, cfg.vocab_size)
        assert bool(jnp.isfinite(lg).all())
