"""Logical-axis sharding rules: divisibility fallback, no double-use of a
physical axis, batch over (pod, data)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.distributed.sharding import spec_for
from repro.models.model import model_template
from repro.models.params import PSpec, param_count


class FakeMesh:
    def __init__(self, shape):
        self.shape = shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH3 = FakeMesh({"pod": 2, "data": 16, "model": 16})


def test_divisible_dims_shard():
    s = spec_for((49152, 576), ("vocab", "embed"), MESH)
    assert s == P("model", "data")


def test_indivisible_dim_replicates():
    # smollm: 9 heads don't divide 16 -> replicate; head_dim stays None
    s = spec_for((576, 9, 64), ("embed", "heads", "head_dim"), MESH)
    assert s == P("data", None, None)


def test_no_double_use():
    # experts take 'model'; mlp would also map to 'model' -> dropped
    s = spec_for((128, 7168, 4864), ("experts", "embed", "mlp"), MESH)
    assert s == P("model", "data", None)


def test_batch_over_pod_and_data():
    s = spec_for((256, 4096), ("batch", "act_seq"), MESH3)
    assert s == P(("pod", "data"), None)
    # batch=1 (long_500k) can't shard -> replicated
    s1 = spec_for((1, 4096), ("batch", "act_seq"), MESH3)
    assert s1 == P(None, None)


def test_batch_partial_divisibility():
    # batch 16 with pod*data=32: drops trailing axes until divisible
    s = spec_for((16, 8), ("batch", None), MESH3)
    assert s == P("pod", None) or s == P(("pod",), None)


def test_every_arch_has_sharded_params():
    """Each arch's biggest params must actually shard (storage feasibility)."""
    for name in ("gemma3-27b", "arctic-480b", "jamba-1.5-large-398b"):
        cfg = get_config(name)
        t = model_template(cfg)
        leaves = jax.tree.leaves(t, is_leaf=lambda x: isinstance(x, PSpec))
        big = sorted(leaves, key=lambda l: -param_count({"x": l}))[:5]
        for spec in big:
            ps = spec_for(spec.shape, spec.axes, MESH)
            assert any(e is not None for e in ps), \
                f"{name}: large tensor {spec.shape} fully replicated"
