import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.models import model as M
from repro.models.layers import ModelOptions


@pytest.fixture(scope="session")
def key():
    return jax.random.PRNGKey(0)


@pytest.fixture(scope="session")
def opts():
    return ModelOptions(remat=False)


_PARAM_CACHE = {}


def reduced_params(name: str, dtype=jnp.float32):
    """Session-cached reduced config + params for an arch."""
    if name not in _PARAM_CACHE:
        cfg = get_config(name).reduced()
        params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0),
                               dtype)
        _PARAM_CACHE[name] = (cfg, params)
    return _PARAM_CACHE[name]
