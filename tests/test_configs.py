"""Config registry: exact assigned specs, param counts, cell enumeration."""
import numpy as np
import pytest

from repro.configs import (ASSIGNED_ARCHS, SHAPES, all_configs, cells,
                           get_config, shape_supported)

EXPECTED = {
    # name: (layers, d_model, heads, kv, d_ff, vocab)
    "whisper-small": (12, 768, 12, 12, 3072, 51865),
    "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
    "smollm-135m": (30, 576, 9, 3, 1536, 49152),
    "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
    "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
    "granite-moe-3b-a800m": (32, 1536, 24, 8, 0, 49155),
    "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
    "internvl2-1b": (24, 896, 14, 2, 4864, 151655),
    "jamba-1.5-large-398b": (72, 8192, 64, 8, 24576, 65536),
    "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
}

# total-params sanity bands (billions)
PARAM_BANDS = {
    "whisper-small": (0.15, 0.30), "qwen1.5-0.5b": (0.35, 0.60),
    "smollm-135m": (0.10, 0.17), "granite-3-2b": (2.0, 3.0),
    "gemma3-27b": (24, 30), "granite-moe-3b-a800m": (2.7, 3.9),
    "arctic-480b": (430, 530), "internvl2-1b": (0.7, 1.1),
    "jamba-1.5-large-398b": (350, 440), "mamba2-780m": (0.65, 0.95),
    "molmoact-7b": (7.0, 8.5),
}


@pytest.mark.parametrize("name", list(EXPECTED))
def test_exact_config(name):
    c = get_config(name)
    assert (c.num_layers, c.d_model, c.num_heads, c.num_kv_heads, c.d_ff,
            c.vocab_size) == EXPECTED[name]


@pytest.mark.parametrize("name", list(PARAM_BANDS))
def test_param_counts(name):
    n = get_config(name).param_counts()["total"] / 1e9
    lo, hi = PARAM_BANDS[name]
    assert lo <= n <= hi, f"{name}: {n:.2f}B not in [{lo},{hi}]"


def test_moe_active_params():
    c = get_config("granite-moe-3b-a800m")
    p = c.param_counts()
    assert 0.6e9 <= p["active"] <= 1.1e9          # ~800M active
    assert p["active"] < p["total"]
    arctic = get_config("arctic-480b").param_counts()
    assert 10e9 <= arctic["active"] <= 20e9       # ~17B active


def test_cell_enumeration():
    all_cells = list(cells(include_skipped=True))
    assert len(all_cells) == 40
    supported = [c for c in all_cells if c[2]]
    assert len(supported) == 33
    # long_500k runs only for sub-quadratic archs
    long_ok = {c[0].name for c in supported if c[1].name == "long_500k"}
    assert long_ok == {"gemma3-27b", "jamba-1.5-large-398b", "mamba2-780m"}


def test_pattern_consistency():
    g = get_config("gemma3-27b")
    ws = g.windows()
    assert ws[5] == 0 and ws[0] == 1024 and len(ws) == 62
    assert sum(1 for w in ws if w == 0) == 10      # global layers
    j = get_config("jamba-1.5-large-398b")
    attn = [i for i in range(j.num_layers) if j.is_attn_layer(i)]
    assert len(attn) == 9                          # 1:7 interleave over 72
    moe = [i for i in range(j.num_layers) if j.is_moe_layer(i)]
    assert len(moe) == 36


def test_reduced_configs_preserve_structure():
    for name, cfg in all_configs().items():
        r = cfg.reduced()
        assert r.family == cfg.family
        assert (r.num_experts > 0) == (cfg.num_experts > 0)
        assert (r.encoder is None) == (cfg.encoder is None)
        assert (r.vision is None) == (cfg.vision is None)
        if cfg.num_heads:
            assert r.num_heads % r.num_kv_heads == 0
