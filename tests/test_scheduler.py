"""Chunked-prefill scheduler: policy math (pure, no model), scheduler
invariants as hypothesis-ready property bodies (budget conservation, class
ordering, decode floor, eviction-victim class safety), engine-level
bit-equality against monolithic prefill, prefix-skip correctness, and the
preempt/requeue interaction with in-flight chunks."""
import math

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.serving import Request, ServingEngine
from repro.serving.scheduler import (BEST_EFFORT, REALTIME, ChunkedScheduler,
                                     PrefillTask, SLOController, SLOTick,
                                     eviction_victims, insert_by_class,
                                     is_realtime, req_deadline)
from conftest import reduced_params


def _streams(cfg, opts, params, reqs, *, n_slots=2, max_seq=64, **kw):
    eng = ServingEngine(cfg, opts, params, n_slots=n_slots, max_seq=max_seq,
                        eos=-999, fused=True, tick_tokens=4, **kw)
    for i, (prompt, max_tokens) in enumerate(reqs):
        eng.submit(Request(uid=i, prompt=prompt.copy(),
                           max_tokens=max_tokens))
    done = eng.run(max_ticks=2_000)
    assert len(done) == len(reqs)
    return {r.uid: r.out_tokens for r in done}, eng


# ---------------------------------------------------------------------------
# policy unit tests (no model)
# ---------------------------------------------------------------------------

def _task(slot, total, n_skip=0):
    return PrefillTask(req=None, slot=slot, total=total, n_skip=n_skip)


def test_plan_decode_reserved_before_prefill():
    """Starvation guarantee: active decoders get their reservation first and
    a long prompt can never take more than the leftover budget per tick."""
    sched = ChunkedScheduler(chunk_size=16, token_budget=48)
    sched.start_task(_task(slot=0, total=400))
    plan = sched.plan_tick(n_active=2, tick_tokens=8)
    assert plan.decode_steps == 8              # min(tick_tokens, 48 // 2)
    chunk_tokens = sum(c.n_tok for c in plan.chunks)
    assert chunk_tokens == 48 - 2 * 8          # prefill only gets the rest
    assert plan.budget_used <= 48


def test_plan_decode_always_advances():
    """Even a budget smaller than the active batch decodes one step."""
    sched = ChunkedScheduler(chunk_size=16, token_budget=4)
    sched.start_task(_task(slot=0, total=100))
    plan = sched.plan_tick(n_active=6, tick_tokens=8)
    assert plan.decode_steps == 1
    assert not plan.chunks                     # nothing left for prefill


def test_plan_progress_floor_without_decoders():
    """token_budget < chunk_size on an idle engine still prefills."""
    sched = ChunkedScheduler(chunk_size=32, token_budget=8)
    sched.start_task(_task(slot=0, total=100))
    plan = sched.plan_tick(n_active=0, tick_tokens=8)
    assert len(plan.chunks) == 1 and plan.chunks[0].n_tok == 8


def test_plan_fcfs_and_partial_final_chunk():
    sched = ChunkedScheduler(chunk_size=16, token_budget=64)
    a = sched.start_task(_task(slot=1, total=21))    # admitted first
    b = sched.start_task(_task(slot=0, total=40))
    plan = sched.plan_tick(n_active=0, tick_tokens=8)
    # task a: 16 + 5 (partial), then task b with what's left (64-21=43)
    assert [(c.task.slot, c.start, c.n_tok) for c in plan.chunks[:2]] == \
        [(1, 0, 16), (1, 16, 5)]
    assert plan.chunks[2].task is b
    assert sum(c.n_tok for c in plan.chunks) <= 64
    # planning must not mutate task positions
    assert a.pos == 0 and b.pos == 0


def test_plan_deprioritizes_stalled_tasks():
    """A stalled task still retries every tick, but healthy tasks get the
    budget first (evicting a progressing task would restart guaranteed
    work, so stalled ones wait their turn instead)."""
    sched = ChunkedScheduler(chunk_size=16, token_budget=32)
    a = sched.start_task(_task(slot=0, total=64))
    b = sched.start_task(_task(slot=1, total=64))
    a.stalled = True
    plan = sched.plan_tick(n_active=0, tick_tokens=8)
    assert plan.chunks and all(c.task is b for c in plan.chunks)
    b.stalled = True                 # both stalled: FCFS retry order
    plan = sched.plan_tick(n_active=0, tick_tokens=8)
    assert plan.chunks[0].task is a


def test_requeue_task_goes_to_front():
    sched = ChunkedScheduler(chunk_size=16, token_budget=32)
    sched.submit("r1")
    task = sched.start_task(PrefillTask(req="r0", slot=0, total=32))
    task.pos = 16                               # chunks already in flight
    sched.requeue_task(0)
    assert sched.waiting == ["r0", "r1"]        # seniority preserved
    assert 0 not in sched.tasks


def test_prefix_skip_starts_at_first_nonshared_token():
    t = _task(slot=0, total=64, n_skip=48)
    sched = ChunkedScheduler(chunk_size=16, token_budget=64)
    sched.start_task(t)
    assert t.pos == 48 and t.remaining == 16
    plan = sched.plan_tick(n_active=0, tick_tokens=8)
    assert plan.chunks[0].start == 48 and plan.chunks[0].n_tok == 16


# ---------------------------------------------------------------------------
# scheduler invariants: hypothesis-ready property bodies
#
# Each ``check_*`` body is a pure function of its drawn inputs, exercised
# here by fixed-draw smokes (so the invariants stay covered without
# hypothesis) and by the ``@given`` wrappers in test_property.py with
# random draws. No model, no jax — plan_tick is host-side policy.
# ---------------------------------------------------------------------------

class _Req:
    """Request double carrying only what the policy layer reads."""

    def __init__(self, uid, priority=BEST_EFFORT, t_deadline=math.inf):
        self.uid = uid
        self.priority = priority
        self.t_deadline = t_deadline

    def __repr__(self):
        return f"_Req({self.uid}, {self.priority}, {self.t_deadline})"


def check_budget_conservation(chunk_size, token_budget, n_active,
                              tick_tokens, totals):
    """One tick never plans more work than the budget allows: chunks fit
    in what the decode reservation leaves, and — whenever the budget can
    cover one decode step per active slot — the whole tick fits inside
    ``token_budget``. The only overdraw the policy permits is the >= 1
    decode-step progress floor when ``token_budget < n_active``."""
    sched = ChunkedScheduler(chunk_size=chunk_size, token_budget=token_budget)
    for i, total in enumerate(totals):
        sched.start_task(_task(slot=i, total=total))
    plan = sched.plan_tick(n_active=n_active, tick_tokens=tick_tokens)
    chunk_tok = sum(c.n_tok for c in plan.chunks)
    assert chunk_tok <= max(0, token_budget - n_active * plan.decode_steps)
    if n_active and token_budget >= n_active:
        assert n_active * plan.decode_steps + chunk_tok <= token_budget
    assert plan.budget_used == n_active * plan.decode_steps + chunk_tok
    # chunks are well-formed: contiguous from each task's position, sized
    # within chunk_size, never past the prompt end
    pos = {}
    for c in plan.chunks:
        assert 1 <= c.n_tok <= chunk_size
        assert c.start == pos.get(c.task.slot, c.task.pos)
        pos[c.task.slot] = c.start + c.n_tok
        assert pos[c.task.slot] <= c.task.total


def check_decode_floor(token_budget, n_active, tick_tokens):
    """Active decoders always advance: >= 1 step regardless of pressure,
    <= tick_tokens regardless of slack (with no SLO boost in play)."""
    sched = ChunkedScheduler(chunk_size=8, token_budget=token_budget)
    plan = sched.plan_tick(n_active=n_active, tick_tokens=tick_tokens)
    if n_active:
        assert 1 <= plan.decode_steps <= tick_tokens
    else:
        assert plan.decode_steps == 0


def check_insert_by_class(specs):
    """Queue shape after arbitrary class-ordered inserts: one realtime
    segment (deadlines non-decreasing) strictly ahead of the best-effort
    segment, and FCFS seniority within each class for plain (front=False)
    arrivals — equal-deadline realtime peers and all best-effort requests
    keep arrival order. ``specs``: (is_rt, deadline, front) per arrival."""
    queue = []
    for i, (rt, dl, front) in enumerate(specs):
        req = _Req(i, REALTIME if rt else BEST_EFFORT,
                   float(dl) if rt else math.inf)
        req.front = front
        insert_by_class(queue, req, front=front)
    k = 0
    while k < len(queue) and is_realtime(queue[k]):
        k += 1
    assert all(not is_realtime(r) for r in queue[k:]), \
        "a best-effort request sits inside the realtime segment"
    dls = [req_deadline(r) for r in queue[:k]]
    assert dls == sorted(dls), f"realtime segment not EDF: {dls}"
    plain_rt = [r.uid for r in queue[:k] if not r.front]
    by_dl = {}
    for r in queue[:k]:
        if not r.front:
            by_dl.setdefault(req_deadline(r), []).append(r.uid)
    for dl, uids in by_dl.items():
        assert uids == sorted(uids), \
            f"equal-deadline realtime arrivals reordered at dl={dl}: {uids}"
    plain_be = [r.uid for r in queue[k:] if not r.front]
    assert plain_be == sorted(plain_be), \
        f"best-effort arrivals reordered: {plain_be}"
    assert len(queue) == len(specs)
    del plain_rt


def check_all_best_effort_degeneracy(fronts):
    """With no realtime requests anywhere, insert_by_class must be
    *bit-identical* to the static policy: append, or insert(0) for
    front=True. This is the anchor for the engine-level guarantee that
    an all-best-effort workload schedules exactly as before the SLO
    scheduler existed."""
    queue, ref = [], []
    for i, front in enumerate(fronts):
        req = _Req(i)
        insert_by_class(queue, req, front=front)
        ref.insert(0, req) if front else ref.append(req)
    assert queue == ref


def check_eviction_victim_class(specs, exclude):
    """Realtime is never an eviction victim, and every stalled best-effort
    task (other than the protected slot) is offered — the policy may not
    silently shrink the victim set either. ``specs``: (is_rt, stalled)."""
    tasks = {}
    for s, (rt, stalled) in enumerate(specs):
        t = _task(slot=s, total=32)
        t.req = _Req(s, REALTIME if rt else BEST_EFFORT)
        t.stalled = stalled
        tasks[s] = t
    victims = eviction_victims(tasks, exclude=exclude)
    assert set(victims) == {
        s for s, t in tasks.items()
        if s != exclude and t.stalled and not is_realtime(t.req)}
    for s in victims:
        assert not is_realtime(tasks[s].req)


def check_slo_quota_and_boost(token_budget, chunk_size, rt_total, be_total,
                              quota, need, n_active, tick_tokens):
    """Under an SLO tick: best-effort chunk tokens never exceed the quota,
    realtime chunks are never quota'd (only budget-bound), and the decode
    reservation honours ``decode_need`` up to ``tick_tokens``. A default
    SLOTick (no pressure) must plan bit-identically to slo=None."""
    def build():
        sched = ChunkedScheduler(chunk_size=chunk_size,
                                 token_budget=token_budget)
        t_rt = _task(slot=0, total=rt_total)
        t_rt.req = _Req(0, REALTIME, t_deadline=1.0)
        sched.start_task(t_rt)
        t_be = _task(slot=1, total=be_total)
        t_be.req = _Req(1)
        sched.start_task(t_be)
        return sched

    plan = build().plan_tick(n_active, tick_tokens,
                             slo=SLOTick(decode_need=need,
                                         be_chunk_quota=quota))
    be_tok = sum(c.n_tok for c in plan.chunks
                 if not is_realtime(c.task.req))
    rt_tok = sum(c.n_tok for c in plan.chunks if is_realtime(c.task.req))
    assert be_tok <= quota
    assert plan.decode_steps <= tick_tokens
    if n_active:
        base = max(1, min(tick_tokens, token_budget // n_active))
        expect = min(tick_tokens, need) if need > base else base
        assert plan.decode_steps == expect
    reserved = n_active * plan.decode_steps
    assert rt_tok + be_tok <= max(0, token_budget - reserved)
    # realtime chunks saw the full leftover, not the best-effort quota
    if quota == 0 and rt_total > 0 and token_budget - reserved > 0:
        assert rt_tok > 0, "quota starved a realtime chunk"
    # no-pressure SLO tick == static plan, field for field
    a = build().plan_tick(n_active, tick_tokens)
    b = build().plan_tick(n_active, tick_tokens, slo=SLOTick())
    assert ([(c.task.slot, c.start, c.n_tok) for c in a.chunks],
            a.decode_steps, a.budget_used) == \
           ([(c.task.slot, c.start, c.n_tok) for c in b.chunks],
            b.decode_steps, b.budget_used)


def test_budget_conservation_fixed_draws():
    check_budget_conservation(16, 48, 2, 8, [400, 37])
    check_budget_conservation(8, 4, 6, 8, [100])       # floor overdraw
    check_budget_conservation(32, 8, 0, 8, [100, 3, 17])


def test_decode_floor_fixed_draws():
    check_decode_floor(4, 6, 8)
    check_decode_floor(64, 1, 4)
    check_decode_floor(16, 0, 8)


def test_insert_by_class_fixed_draws():
    check_insert_by_class([(False, None, False), (True, 3.0, False),
                           (True, 1.0, False), (False, None, True),
                           (True, 3.0, False), (True, 2.0, True),
                           (False, None, False)])
    check_all_best_effort_degeneracy([False, True, False, False, True])


def test_eviction_victim_class_fixed_draws():
    check_eviction_victim_class([(True, True), (False, True),
                                 (False, False), (True, False)], exclude=-1)
    check_eviction_victim_class([(False, True), (False, True)], exclude=0)


def test_slo_quota_and_boost_fixed_draws():
    check_slo_quota_and_boost(32, 16, 40, 40, 0, 6, 2, 8)
    check_slo_quota_and_boost(48, 16, 64, 64, 8, 0, 1, 4)


def test_slo_controller_math():
    """need = max over slots of ceil(remaining / floor(slack/ewma));
    pressure when slack < safety * remaining * ewma or realtime prefill
    is pending; finished / undeadlined slots are ignored."""
    ctl = SLOController(slo_hz=10.0, safety=2.0)
    tick = ctl.plan(now=0.0, tick_ewma_s=0.01,
                    rt_decode=[(12, 0.04), (3, 0.10)],
                    rt_prefill_pending=False)
    # slot 1: slack 0.04 -> 4 ticks -> ceil(12/4) = 3/tick; pressure
    # (0.04 < 2 * 12 * 0.01); slot 2 comfortable (ceil(3/10) = 1)
    assert tick.decode_need == 3 and tick.be_chunk_quota == 0
    tick = ctl.plan(0.0, 0.01, [(4, 1.0)], rt_prefill_pending=False)
    assert tick.decode_need == 1 and tick.be_chunk_quota is None
    tick = ctl.plan(0.0, 0.01, [(0, 0.001), (5, math.inf)],
                    rt_prefill_pending=False)
    assert tick.decode_need == 0 and tick.be_chunk_quota is None
    tick = ctl.plan(0.0, 0.01, [], rt_prefill_pending=True)
    assert tick.be_chunk_quota == 0
    with pytest.raises(ValueError, match="slo_hz"):
        SLOController(slo_hz=0.0)


# ---------------------------------------------------------------------------
# engine-level: bit-equality and edge cases
# ---------------------------------------------------------------------------

def check_chunk_invariance(chunk, paged, lens):
    """Greedy streams from a chunked engine must equal the monolithic dense
    baseline bit-for-bit, for any chunk size and prompt lengths. Driven by
    the fixed-draw smoke below, and by the hypothesis property in
    test_property.py with random draws (this module stays importable
    without hypothesis, so the body is usable in both environments)."""
    cfg, params = reduced_params("qwen1.5-0.5b")
    opts = ModelOptions(remat=False)
    page_size = 4
    kw = dict(paged=True, page_size=page_size) if paged else {}
    if paged:                       # chunk writes must start page-aligned
        chunk = max(page_size, chunk - chunk % page_size)
    rng = np.random.default_rng(chunk * 101 + len(lens))
    reqs = [(rng.integers(0, cfg.vocab_size, n, dtype=np.int32), 4)
            for n in lens]
    base, _ = _streams(cfg, opts, params, reqs)
    chunked, _ = _streams(cfg, opts, params, reqs, chunked_prefill=True,
                          chunk_size=chunk, token_budget=max(16, chunk),
                          **kw)
    assert chunked == base, \
        f"chunk={chunk} paged={paged} lens={lens}: streams diverged"


@pytest.mark.parametrize("chunk,paged,lens", [
    (7, False, [13, 37]),           # odd chunk, non-aligned prompts
    (10, True, [9, 40, 1]),         # paged, chunk snapped to page multiple
])
def test_chunk_invariance_fixed_draws(chunk, paged, lens):
    check_chunk_invariance(chunk, paged, lens)


def test_chunked_matches_monolithic_dense_and_paged(opts):
    """Chunk size that divides nothing (5 into prompts of 13/9/21) must
    still produce greedy streams bit-identical to the admit-stall
    monolithic baseline, on both layouts."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, cfg.vocab_size, l, dtype=np.int32), m)
            for l, m in [(13, 7), (9, 5), (21, 8), (5, 6)]]
    base, _ = _streams(cfg, opts, params, reqs)
    dense, e_d = _streams(cfg, opts, params, reqs, chunked_prefill=True,
                          chunk_size=5, token_budget=20)
    assert dense == base
    paged, e_p = _streams(cfg, opts, params, reqs, chunked_prefill=True,
                          chunk_size=8, token_budget=20, paged=True,
                          page_size=8)
    assert paged == base
    total = sum(len(p) for p, _ in reqs)
    for e in (e_d, e_p):
        assert e.stats.prefill_tokens + e.stats.prefill_skipped == total
        assert len(e.stats.ttft_s) == len(reqs)
        assert len(e.stats.queue_s) == len(reqs)


def test_chunk_larger_than_prompt_single_dispatch(opts):
    """chunk_size > prompt: one padded chunk, still bit-identical."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(1)
    reqs = [(rng.integers(0, cfg.vocab_size, 7, dtype=np.int32), 5)]
    base, _ = _streams(cfg, opts, params, reqs, n_slots=1)
    ch, eng = _streams(cfg, opts, params, reqs, n_slots=1,
                       chunked_prefill=True, chunk_size=32, token_budget=32)
    assert ch == base and eng.stats.prefill_tokens == 7


def test_prefix_hit_covering_entire_prompt(opts):
    """A full-prompt prefix hit skips everything except the final page
    (whose last-position logits seed decoding) and still emits the same
    stream as the first run."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)  # 2 pages
    eng = ServingEngine(cfg, opts, params, n_slots=2, max_seq=64, eos=-999,
                        fused=True, tick_tokens=4, chunked_prefill=True,
                        chunk_size=8, token_budget=24, paged=True,
                        page_size=8)
    eng.submit(Request(uid=0, prompt=prompt.copy(), max_tokens=5))
    eng.run()
    run1 = eng.stats.prefill_tokens
    eng.submit(Request(uid=1, prompt=prompt.copy(), max_tokens=5))
    done = eng.run()
    r0, r1 = sorted(done, key=lambda r: r.uid)
    assert r1.out_tokens == r0.out_tokens
    assert run1 == 16                          # first run computed all of it
    assert r1.prefill_skipped == 8             # all but the final page
    assert eng.stats.prefill_tokens == 16 + 8  # repeat ran only 8 tokens
    assert r1.pages_shared >= 1


def test_preempt_requeue_with_inflight_chunks(opts):
    """A pool too small for everyone forces mid-prefill preemption; the
    requeued request restarts (possibly prefix-skipping its own first
    attempt's pages) and every stream still matches the ample-pool run."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(3)
    reqs = [(rng.integers(0, cfg.vocab_size, 20, dtype=np.int32), 8),
            (rng.integers(0, cfg.vocab_size, 24, dtype=np.int32), 6),
            (rng.integers(0, cfg.vocab_size, 12, dtype=np.int32), 5)]
    base, _ = _streams(cfg, opts, params, reqs, n_slots=3)
    tiny, eng = _streams(cfg, opts, params, reqs, n_slots=3,
                         chunked_prefill=True, chunk_size=8, token_budget=16,
                         paged=True, page_size=8, num_pages=9,
                         reserve_pages=1)
    assert tiny == base
    assert eng.pool.pages_in_use == 0          # all pages returned


def test_decode_tick_does_not_clobber_inflight_prefill(opts):
    """Regression: the fused tick writes KV for every slot row, done or
    not; a mid-prefill slot's page-table row must be nulled in the decode
    snapshot or stale decode indices overwrite freshly-written chunk KV."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(4)
    # one decoding request, then a second arrives so its chunks interleave
    # with the first one's decode ticks
    reqs = [(rng.integers(0, cfg.vocab_size, 6, dtype=np.int32), 12),
            (rng.integers(0, cfg.vocab_size, 24, dtype=np.int32), 5)]
    base, _ = _streams(cfg, opts, params, reqs)
    ch, _ = _streams(cfg, opts, params, reqs, chunked_prefill=True,
                     chunk_size=8, token_budget=10, paged=True, page_size=8)
    assert ch == base


def test_chunked_engine_validations(opts):
    cfg, params = reduced_params("smollm-135m")
    with pytest.raises(ValueError, match="fused"):
        ServingEngine(cfg, opts, params, fused=False, chunked_prefill=True)
    with pytest.raises(ValueError, match="page_size"):
        ServingEngine(cfg, opts, params, chunked_prefill=True, paged=True,
                      page_size=16, chunk_size=24, max_seq=64)
    ring = ModelOptions(remat=False, window_cache=True)
    with pytest.raises(ValueError, match="window_cache"):
        ServingEngine(cfg, ring, params, chunked_prefill=True)
    # kernel path: the paged chunk kernel partitions the key axis per page,
    # so bit-equality vs the dense kernel's bands needs the two to match
    pallas = ModelOptions(remat=False, use_pallas=True, prefill_band=32)
    with pytest.raises(ValueError, match="prefill_band"):
        ServingEngine(cfg, pallas, params, chunked_prefill=True, paged=True,
                      page_size=16, chunk_size=16, max_seq=64)
    ServingEngine(cfg, pallas, params, chunked_prefill=True, paged=True,
                  page_size=32, chunk_size=32, max_seq=64)  # aligned: fine
    cfg_ssm, params_ssm = reduced_params("mamba2-780m")
    with pytest.raises(ValueError, match="attention-only"):
        ServingEngine(cfg_ssm, opts, params_ssm, chunked_prefill=True)


def test_phase_report_percentiles_and_ttft(opts):
    """EngineStats: per-request ttft/queue populated and phase_report
    carries decode-tick percentiles on legacy engines too."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(5)
    reqs = [(rng.integers(0, cfg.vocab_size, 6, dtype=np.int32), 6)
            for _ in range(3)]
    _, eng = _streams(cfg, opts, params, reqs)
    rep = eng.stats.phase_report()
    assert {"decode_tick_p50", "decode_tick_p99"} <= rep.keys()
    assert rep["decode_tick_p99"] >= rep["decode_tick_p50"] > 0
    assert len(eng.stats.ttft_s) == 3
    for r in eng.finished:
        assert r.ttft_s >= r.queue_s >= 0


def test_realtime_jumps_best_effort_backlog(opts):
    """A realtime control request submitted behind a best-effort backlog is
    admitted class-first, finishes first, and scores its deadline in the
    per-class scoreboard."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(9)
    reqs = [(rng.integers(0, cfg.vocab_size, 48, dtype=np.int32), 6)
            for _ in range(3)]
    eng = ServingEngine(cfg, opts, params, n_slots=2, max_seq=64, eos=-999,
                        fused=True, tick_tokens=4, paged=True, page_size=8,
                        chunked_prefill=True, chunk_size=16, token_budget=16,
                        slo_hz=20.0)
    for i, (p, m) in enumerate(reqs):
        eng.submit(Request(uid=i, prompt=p.copy(), max_tokens=m))
    eng.submit(Request(uid=99,
                       prompt=rng.integers(0, cfg.vocab_size, 8,
                                           dtype=np.int32),
                       max_tokens=4, priority="realtime", deadline_s=60.0))
    done = eng.run(max_ticks=2_000)
    assert len(done) == 4
    assert done[0].uid == 99, \
        f"realtime request finished {[r.uid for r in done].index(99) + 1}th"
    rep = eng.stats.phase_report()
    assert rep["deadline_total_realtime"] == 1.0
    assert rep["deadline_attainment_realtime"] == 1.0
    assert rep["tick_ewma_s"] > 0


def test_slo_engine_bit_equal_on_best_effort_workload(opts):
    """With no realtime traffic and no deadlines, an slo_hz engine must
    generate bit-identically to the static scheduler — the SLO controller
    is a strict no-op without deadline pressure."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(10)
    reqs = [(rng.integers(0, cfg.vocab_size, l, dtype=np.int32), m)
            for l, m in [(13, 6), (29, 4), (7, 7)]]
    kw = dict(chunked_prefill=True, chunk_size=16, token_budget=16,
              paged=True, page_size=8)
    base, _ = _streams(cfg, opts, params, reqs, **kw)
    slo, _ = _streams(cfg, opts, params, reqs, slo_hz=10.0, **kw)
    assert slo == base


def test_slo_hz_engine_validation(opts):
    cfg, params = reduced_params("smollm-135m")
    with pytest.raises(ValueError, match="slo_hz"):
        ServingEngine(cfg, opts, params, n_slots=2, max_seq=64, eos=-999,
                      chunked_prefill=True, slo_hz=-1.0)
    with pytest.raises(ValueError, match="chunked_prefill"):
        ServingEngine(cfg, opts, params, n_slots=2, max_seq=64, eos=-999,
                      slo_hz=10.0)


def test_positioned_prefill_model_api(opts):
    """model.prefill(cache_index>0): suffix prefill over existing caches is
    bit-identical to one monolithic call."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, 12, dtype=np.int32)
    lg_m, _ = M.prefill(cfg, opts, params,
                        {"tokens": jnp.asarray(prompt[None])}, 32,
                        cache_dtype=jnp.float32)
    lg_a, caches = M.prefill(cfg, opts, params,
                             {"tokens": jnp.asarray(prompt[None, :5])}, 32,
                             cache_dtype=jnp.float32)
    lg_b, _ = M.prefill(cfg, opts, params,
                        {"tokens": jnp.asarray(prompt[None, 5:])}, 32,
                        caches=caches, cache_index=5)
    assert (jnp.asarray(lg_b) == jnp.asarray(lg_m)).all()
    with pytest.raises(ValueError, match="existing caches"):
        M.prefill(cfg, opts, params, {"tokens": jnp.asarray(prompt[None])},
                  32, cache_index=5)
