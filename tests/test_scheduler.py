"""Chunked-prefill scheduler: policy math (pure, no model), engine-level
bit-equality against monolithic prefill, prefix-skip correctness, and the
preempt/requeue interaction with in-flight chunks."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.serving import Request, ServingEngine
from repro.serving.scheduler import ChunkedScheduler, PrefillTask
from conftest import reduced_params


def _streams(cfg, opts, params, reqs, *, n_slots=2, max_seq=64, **kw):
    eng = ServingEngine(cfg, opts, params, n_slots=n_slots, max_seq=max_seq,
                        eos=-999, fused=True, tick_tokens=4, **kw)
    for i, (prompt, max_tokens) in enumerate(reqs):
        eng.submit(Request(uid=i, prompt=prompt.copy(),
                           max_tokens=max_tokens))
    done = eng.run(max_ticks=2_000)
    assert len(done) == len(reqs)
    return {r.uid: r.out_tokens for r in done}, eng


# ---------------------------------------------------------------------------
# policy unit tests (no model)
# ---------------------------------------------------------------------------

def _task(slot, total, n_skip=0):
    return PrefillTask(req=None, slot=slot, total=total, n_skip=n_skip)


def test_plan_decode_reserved_before_prefill():
    """Starvation guarantee: active decoders get their reservation first and
    a long prompt can never take more than the leftover budget per tick."""
    sched = ChunkedScheduler(chunk_size=16, token_budget=48)
    sched.start_task(_task(slot=0, total=400))
    plan = sched.plan_tick(n_active=2, tick_tokens=8)
    assert plan.decode_steps == 8              # min(tick_tokens, 48 // 2)
    chunk_tokens = sum(c.n_tok for c in plan.chunks)
    assert chunk_tokens == 48 - 2 * 8          # prefill only gets the rest
    assert plan.budget_used <= 48


def test_plan_decode_always_advances():
    """Even a budget smaller than the active batch decodes one step."""
    sched = ChunkedScheduler(chunk_size=16, token_budget=4)
    sched.start_task(_task(slot=0, total=100))
    plan = sched.plan_tick(n_active=6, tick_tokens=8)
    assert plan.decode_steps == 1
    assert not plan.chunks                     # nothing left for prefill


def test_plan_progress_floor_without_decoders():
    """token_budget < chunk_size on an idle engine still prefills."""
    sched = ChunkedScheduler(chunk_size=32, token_budget=8)
    sched.start_task(_task(slot=0, total=100))
    plan = sched.plan_tick(n_active=0, tick_tokens=8)
    assert len(plan.chunks) == 1 and plan.chunks[0].n_tok == 8


def test_plan_fcfs_and_partial_final_chunk():
    sched = ChunkedScheduler(chunk_size=16, token_budget=64)
    a = sched.start_task(_task(slot=1, total=21))    # admitted first
    b = sched.start_task(_task(slot=0, total=40))
    plan = sched.plan_tick(n_active=0, tick_tokens=8)
    # task a: 16 + 5 (partial), then task b with what's left (64-21=43)
    assert [(c.task.slot, c.start, c.n_tok) for c in plan.chunks[:2]] == \
        [(1, 0, 16), (1, 16, 5)]
    assert plan.chunks[2].task is b
    assert sum(c.n_tok for c in plan.chunks) <= 64
    # planning must not mutate task positions
    assert a.pos == 0 and b.pos == 0


def test_plan_deprioritizes_stalled_tasks():
    """A stalled task still retries every tick, but healthy tasks get the
    budget first (evicting a progressing task would restart guaranteed
    work, so stalled ones wait their turn instead)."""
    sched = ChunkedScheduler(chunk_size=16, token_budget=32)
    a = sched.start_task(_task(slot=0, total=64))
    b = sched.start_task(_task(slot=1, total=64))
    a.stalled = True
    plan = sched.plan_tick(n_active=0, tick_tokens=8)
    assert plan.chunks and all(c.task is b for c in plan.chunks)
    b.stalled = True                 # both stalled: FCFS retry order
    plan = sched.plan_tick(n_active=0, tick_tokens=8)
    assert plan.chunks[0].task is a


def test_requeue_task_goes_to_front():
    sched = ChunkedScheduler(chunk_size=16, token_budget=32)
    sched.submit("r1")
    task = sched.start_task(PrefillTask(req="r0", slot=0, total=32))
    task.pos = 16                               # chunks already in flight
    sched.requeue_task(0)
    assert sched.waiting == ["r0", "r1"]        # seniority preserved
    assert 0 not in sched.tasks


def test_prefix_skip_starts_at_first_nonshared_token():
    t = _task(slot=0, total=64, n_skip=48)
    sched = ChunkedScheduler(chunk_size=16, token_budget=64)
    sched.start_task(t)
    assert t.pos == 48 and t.remaining == 16
    plan = sched.plan_tick(n_active=0, tick_tokens=8)
    assert plan.chunks[0].start == 48 and plan.chunks[0].n_tok == 16


# ---------------------------------------------------------------------------
# engine-level: bit-equality and edge cases
# ---------------------------------------------------------------------------

def check_chunk_invariance(chunk, paged, lens):
    """Greedy streams from a chunked engine must equal the monolithic dense
    baseline bit-for-bit, for any chunk size and prompt lengths. Driven by
    the fixed-draw smoke below, and by the hypothesis property in
    test_property.py with random draws (this module stays importable
    without hypothesis, so the body is usable in both environments)."""
    cfg, params = reduced_params("qwen1.5-0.5b")
    opts = ModelOptions(remat=False)
    page_size = 4
    kw = dict(paged=True, page_size=page_size) if paged else {}
    if paged:                       # chunk writes must start page-aligned
        chunk = max(page_size, chunk - chunk % page_size)
    rng = np.random.default_rng(chunk * 101 + len(lens))
    reqs = [(rng.integers(0, cfg.vocab_size, n, dtype=np.int32), 4)
            for n in lens]
    base, _ = _streams(cfg, opts, params, reqs)
    chunked, _ = _streams(cfg, opts, params, reqs, chunked_prefill=True,
                          chunk_size=chunk, token_budget=max(16, chunk),
                          **kw)
    assert chunked == base, \
        f"chunk={chunk} paged={paged} lens={lens}: streams diverged"


@pytest.mark.parametrize("chunk,paged,lens", [
    (7, False, [13, 37]),           # odd chunk, non-aligned prompts
    (10, True, [9, 40, 1]),         # paged, chunk snapped to page multiple
])
def test_chunk_invariance_fixed_draws(chunk, paged, lens):
    check_chunk_invariance(chunk, paged, lens)


def test_chunked_matches_monolithic_dense_and_paged(opts):
    """Chunk size that divides nothing (5 into prompts of 13/9/21) must
    still produce greedy streams bit-identical to the admit-stall
    monolithic baseline, on both layouts."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, cfg.vocab_size, l, dtype=np.int32), m)
            for l, m in [(13, 7), (9, 5), (21, 8), (5, 6)]]
    base, _ = _streams(cfg, opts, params, reqs)
    dense, e_d = _streams(cfg, opts, params, reqs, chunked_prefill=True,
                          chunk_size=5, token_budget=20)
    assert dense == base
    paged, e_p = _streams(cfg, opts, params, reqs, chunked_prefill=True,
                          chunk_size=8, token_budget=20, paged=True,
                          page_size=8)
    assert paged == base
    total = sum(len(p) for p, _ in reqs)
    for e in (e_d, e_p):
        assert e.stats.prefill_tokens + e.stats.prefill_skipped == total
        assert len(e.stats.ttft_s) == len(reqs)
        assert len(e.stats.queue_s) == len(reqs)


def test_chunk_larger_than_prompt_single_dispatch(opts):
    """chunk_size > prompt: one padded chunk, still bit-identical."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(1)
    reqs = [(rng.integers(0, cfg.vocab_size, 7, dtype=np.int32), 5)]
    base, _ = _streams(cfg, opts, params, reqs, n_slots=1)
    ch, eng = _streams(cfg, opts, params, reqs, n_slots=1,
                       chunked_prefill=True, chunk_size=32, token_budget=32)
    assert ch == base and eng.stats.prefill_tokens == 7


def test_prefix_hit_covering_entire_prompt(opts):
    """A full-prompt prefix hit skips everything except the final page
    (whose last-position logits seed decoding) and still emits the same
    stream as the first run."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)  # 2 pages
    eng = ServingEngine(cfg, opts, params, n_slots=2, max_seq=64, eos=-999,
                        fused=True, tick_tokens=4, chunked_prefill=True,
                        chunk_size=8, token_budget=24, paged=True,
                        page_size=8)
    eng.submit(Request(uid=0, prompt=prompt.copy(), max_tokens=5))
    eng.run()
    run1 = eng.stats.prefill_tokens
    eng.submit(Request(uid=1, prompt=prompt.copy(), max_tokens=5))
    done = eng.run()
    r0, r1 = sorted(done, key=lambda r: r.uid)
    assert r1.out_tokens == r0.out_tokens
    assert run1 == 16                          # first run computed all of it
    assert r1.prefill_skipped == 8             # all but the final page
    assert eng.stats.prefill_tokens == 16 + 8  # repeat ran only 8 tokens
    assert r1.pages_shared >= 1


def test_preempt_requeue_with_inflight_chunks(opts):
    """A pool too small for everyone forces mid-prefill preemption; the
    requeued request restarts (possibly prefix-skipping its own first
    attempt's pages) and every stream still matches the ample-pool run."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(3)
    reqs = [(rng.integers(0, cfg.vocab_size, 20, dtype=np.int32), 8),
            (rng.integers(0, cfg.vocab_size, 24, dtype=np.int32), 6),
            (rng.integers(0, cfg.vocab_size, 12, dtype=np.int32), 5)]
    base, _ = _streams(cfg, opts, params, reqs, n_slots=3)
    tiny, eng = _streams(cfg, opts, params, reqs, n_slots=3,
                         chunked_prefill=True, chunk_size=8, token_budget=16,
                         paged=True, page_size=8, num_pages=9,
                         reserve_pages=1)
    assert tiny == base
    assert eng.pool.pages_in_use == 0          # all pages returned


def test_decode_tick_does_not_clobber_inflight_prefill(opts):
    """Regression: the fused tick writes KV for every slot row, done or
    not; a mid-prefill slot's page-table row must be nulled in the decode
    snapshot or stale decode indices overwrite freshly-written chunk KV."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(4)
    # one decoding request, then a second arrives so its chunks interleave
    # with the first one's decode ticks
    reqs = [(rng.integers(0, cfg.vocab_size, 6, dtype=np.int32), 12),
            (rng.integers(0, cfg.vocab_size, 24, dtype=np.int32), 5)]
    base, _ = _streams(cfg, opts, params, reqs)
    ch, _ = _streams(cfg, opts, params, reqs, chunked_prefill=True,
                     chunk_size=8, token_budget=10, paged=True, page_size=8)
    assert ch == base


def test_chunked_engine_validations(opts):
    cfg, params = reduced_params("smollm-135m")
    with pytest.raises(ValueError, match="fused"):
        ServingEngine(cfg, opts, params, fused=False, chunked_prefill=True)
    with pytest.raises(ValueError, match="page_size"):
        ServingEngine(cfg, opts, params, chunked_prefill=True, paged=True,
                      page_size=16, chunk_size=24, max_seq=64)
    ring = ModelOptions(remat=False, window_cache=True)
    with pytest.raises(ValueError, match="window_cache"):
        ServingEngine(cfg, ring, params, chunked_prefill=True)
    # kernel path: the paged chunk kernel partitions the key axis per page,
    # so bit-equality vs the dense kernel's bands needs the two to match
    pallas = ModelOptions(remat=False, use_pallas=True, prefill_band=32)
    with pytest.raises(ValueError, match="prefill_band"):
        ServingEngine(cfg, pallas, params, chunked_prefill=True, paged=True,
                      page_size=16, chunk_size=16, max_seq=64)
    ServingEngine(cfg, pallas, params, chunked_prefill=True, paged=True,
                  page_size=32, chunk_size=32, max_seq=64)  # aligned: fine
    cfg_ssm, params_ssm = reduced_params("mamba2-780m")
    with pytest.raises(ValueError, match="attention-only"):
        ServingEngine(cfg_ssm, opts, params_ssm, chunked_prefill=True)


def test_phase_report_percentiles_and_ttft(opts):
    """EngineStats: per-request ttft/queue populated and phase_report
    carries decode-tick percentiles on legacy engines too."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(5)
    reqs = [(rng.integers(0, cfg.vocab_size, 6, dtype=np.int32), 6)
            for _ in range(3)]
    _, eng = _streams(cfg, opts, params, reqs)
    rep = eng.stats.phase_report()
    assert {"decode_tick_p50", "decode_tick_p99"} <= rep.keys()
    assert rep["decode_tick_p99"] >= rep["decode_tick_p50"] > 0
    assert len(eng.stats.ttft_s) == 3
    for r in eng.finished:
        assert r.ttft_s >= r.queue_s >= 0


def test_positioned_prefill_model_api(opts):
    """model.prefill(cache_index>0): suffix prefill over existing caches is
    bit-identical to one monolithic call."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(6)
    prompt = rng.integers(0, cfg.vocab_size, 12, dtype=np.int32)
    lg_m, _ = M.prefill(cfg, opts, params,
                        {"tokens": jnp.asarray(prompt[None])}, 32,
                        cache_dtype=jnp.float32)
    lg_a, caches = M.prefill(cfg, opts, params,
                             {"tokens": jnp.asarray(prompt[None, :5])}, 32,
                             cache_dtype=jnp.float32)
    lg_b, _ = M.prefill(cfg, opts, params,
                        {"tokens": jnp.asarray(prompt[None, 5:])}, 32,
                        caches=caches, cache_index=5)
    assert (jnp.asarray(lg_b) == jnp.asarray(lg_m)).all()
    with pytest.raises(ValueError, match="existing caches"):
        M.prefill(cfg, opts, params, {"tokens": jnp.asarray(prompt[None])},
                  32, cache_index=5)
