"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
pure-jnp oracle (interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.decode_attention import decode_attention
from repro.kernels.decode_attention import ref as dref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention import ref as fref
from repro.kernels.moe_gmm import grouped_mlp
from repro.kernels.moe_gmm import ref as gref
from repro.kernels.ssd import ssd
from repro.kernels.ssd import ref as sref

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,S,N,K,h", [
    (2, 256, 4, 2, 64), (1, 256, 8, 8, 64), (2, 128, 6, 2, 32),
    (1, 512, 4, 1, 128), (2, 256, 16, 4, 64),
])
@pytest.mark.parametrize("window", [0, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, N, K, h, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, N, h), dtype)
    k = jax.random.normal(ks[1], (B, S, K, h), dtype)
    v = jax.random.normal(ks[2], (B, S, K, h), dtype)
    out = flash_attention(q, k, v, window=window, interpret=True)
    exp = fref.attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,N,K,h,idx", [
    (2, 1024, 8, 2, 64, 700), (1, 512, 4, 4, 64, 511),
    (2, 1024, 16, 4, 128, 900), (1, 512, 8, 1, 64, 0),
    (3, 768, 6, 2, 32, 300),
])
@pytest.mark.parametrize("window", [0, 256])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, S, N, K, h, idx, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, N, h), dtype)
    kc = jax.random.normal(ks[1], (B, S, K, h), dtype)
    vc = jax.random.normal(ks[2], (B, S, K, h), dtype)
    out = decode_attention(q, kc, vc, idx, window=window, bk=256,
                           interpret=True)
    exp = dref.decode_attention_ref(q, kc, vc, idx, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,H,P,N,Q", [
    (2, 256, 4, 64, 128, 128), (1, 128, 2, 32, 64, 64),
    (2, 512, 3, 64, 128, 128), (1, 256, 8, 16, 32, 64),
])
def test_ssd_vs_sequential(B, S, H, P, N, Q):
    ks = jax.random.split(KEY, 5)
    xs = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A_log = jax.random.uniform(ks[2], (H,), minval=0.0, maxval=1.5)
    B_ = 0.3 * jax.random.normal(ks[3], (B, S, 1, N), jnp.float32)
    C_ = 0.3 * jax.random.normal(ks[4], (B, S, 1, N), jnp.float32)
    y, st = ssd(xs, dt, A_log, B_, C_, Q=Q, interpret=True)
    y_ref, st_ref = sref.ssd_scan_ref(xs, dt, A_log, B_, C_)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               atol=5e-4, rtol=5e-3)


def test_ssd_chunked_matches_kernel():
    """The XLA fallback (ssd_chunked) and the Pallas kernel agree."""
    ks = jax.random.split(KEY, 5)
    B, S, H, P, N = 2, 256, 4, 32, 64
    xs = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A_log = jax.random.uniform(ks[2], (H,), minval=0.0, maxval=1.5)
    B_ = 0.3 * jax.random.normal(ks[3], (B, S, 1, N))
    C_ = 0.3 * jax.random.normal(ks[4], (B, S, 1, N))
    y1, s1 = ssd(xs, dt, A_log, B_, C_, interpret=True)
    y2, s2 = sref.ssd_chunked(xs, dt, A_log, B_, C_)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("E,C,D,F,act", [
    (4, 128, 256, 512, "silu"), (8, 64, 128, 96, "gelu"),
    (2, 256, 64, 128, "gelu_plain"), (16, 32, 64, 64, "silu"),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm(E, C, D, F, act, dtype):
    ks = jax.random.split(KEY, 4)
    xe = 0.3 * jax.random.normal(ks[0], (E, C, D), dtype)
    wi = 0.3 * jax.random.normal(ks[1], (E, D, F), dtype)
    wg = 0.3 * jax.random.normal(ks[2], (E, D, F), dtype)
    wo = 0.3 * jax.random.normal(ks[3], (E, F, D), dtype)
    out = grouped_mlp(xe, wi, wg, wo, act, interpret=True)
    exp = np.asarray(gref.grouped_mlp_ref(xe, wi, wg, wo, act), np.float32)
    # bf16: the intermediate h is quantized in both kernel and ref; error
    # scales with output magnitude (two D/F-deep accumulations), so atol
    # scales with max|exp| (~bf16 eps of the output scale)
    tol = _tol(dtype)
    if dtype == jnp.bfloat16:
        tol = dict(atol=0.02 * float(np.abs(exp).max()) + 1e-3, rtol=5e-2)
    np.testing.assert_allclose(np.asarray(out, np.float32), exp, **tol)
