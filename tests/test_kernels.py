"""Per-kernel validation: sweep shapes/dtypes, assert_allclose against the
pure-jnp oracle (interpret=True executes the kernel body on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.chunk_prefill import (chunk_prefill_attention,
                                         paged_chunk_prefill_attention)
from repro.kernels.chunk_prefill import ref as cref
from repro.kernels.decode_attention import (decode_attention,
                                            paged_decode_attention)
from repro.kernels.decode_attention import ref as dref
from repro.kernels.flash_attention import flash_attention
from repro.kernels.flash_attention import ref as fref
from repro.kernels.moe_gmm import grouped_mlp
from repro.kernels.moe_gmm import ref as gref
from repro.kernels.ssd import ssd
from repro.kernels.ssd import ref as sref

KEY = jax.random.PRNGKey(42)


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,S,N,K,h", [
    (2, 256, 4, 2, 64), (1, 256, 8, 8, 64), (2, 128, 6, 2, 32),
    (1, 512, 4, 1, 128), (2, 256, 16, 4, 64),
])
@pytest.mark.parametrize("window", [0, 128])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, S, N, K, h, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, N, h), dtype)
    k = jax.random.normal(ks[1], (B, S, K, h), dtype)
    v = jax.random.normal(ks[2], (B, S, K, h), dtype)
    out = flash_attention(q, k, v, window=window, interpret=True)
    exp = fref.attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,N,K,h,idx", [
    (2, 1024, 8, 2, 64, 700), (1, 512, 4, 4, 64, 511),
    (2, 1024, 16, 4, 128, 900), (1, 512, 8, 1, 64, 0),
    (3, 768, 6, 2, 32, 300),
])
@pytest.mark.parametrize("window", [0, 256])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_decode_attention(B, S, N, K, h, idx, window, dtype):
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, N, h), dtype)
    kc = jax.random.normal(ks[1], (B, S, K, h), dtype)
    vc = jax.random.normal(ks[2], (B, S, K, h), dtype)
    out = decode_attention(q, kc, vc, idx, window=window, bk=256,
                           interpret=True)
    exp = dref.decode_attention_ref(q, kc, vc, idx, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("B,S,N,K,h,bk", [
    (3, 768, 8, 2, 64, 256), (2, 512, 4, 4, 32, 512), (4, 384, 6, 2, 32, 128),
])
@pytest.mark.parametrize("window", [0, 200])
def test_decode_attention_per_slot_index(B, S, N, K, h, bk, window):
    """Per-slot [B] index vectors (continuous batching): every slot masks
    and early-exits against its own position."""
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, N, h))
    kc = jax.random.normal(ks[1], (B, S, K, h))
    vc = jax.random.normal(ks[2], (B, S, K, h))
    idx = jax.random.randint(ks[3], (B,), 0, S, jnp.int32)
    out = decode_attention(q, kc, vc, idx, window=window, bk=bk,
                           interpret=True)
    exp = dref.decode_attention_ref(q, kc, vc, idx, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("S,bk,idx", [
    (600, 512, 599),   # the regression: nk = S // bk used to drop 88 tail
    (600, 512, 100),   # positions silently whenever S % bk != 0
    (130, 64, 129),
    (48, 512, 47),     # bk > S: single padded block
])
@pytest.mark.parametrize("window", [0, 96])
def test_decode_attention_non_block_aligned(S, bk, idx, window):
    """S % bk != 0 must not drop the KV tail (positions >= (S//bk)*bk)."""
    B, N, K, h = 2, 4, 2, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, N, h))
    kc = jax.random.normal(ks[1], (B, S, K, h))
    vc = jax.random.normal(ks[2], (B, S, K, h))
    out = decode_attention(q, kc, vc, idx, window=window, bk=bk,
                           interpret=True)
    exp = dref.decode_attention_ref(q, kc, vc, idx, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,npg,ps,N,K,h", [
    (3, 8, 16, 8, 2, 64), (2, 4, 32, 4, 4, 32), (1, 16, 8, 6, 1, 32),
])
@pytest.mark.parametrize("window", [0, 40])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_decode_attention(B, npg, ps, N, K, h, window, dtype):
    """Paged kernel (page-table gather via scalar-prefetched index map)
    against the gather-then-dense oracle, with a scrambled page table so
    physical order != logical order."""
    P = B * npg + 3
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, N, h), dtype)
    kp = jax.random.normal(ks[1], (P, ps, K, h), dtype)
    vp = jax.random.normal(ks[2], (P, ps, K, h), dtype)
    # distinct physical pages, never the null page 0, scrambled order
    perm = jax.random.permutation(ks[3], jnp.arange(1, P))[:B * npg]
    pt = perm.reshape(B, npg).astype(jnp.int32)
    idx = jax.random.randint(ks[3], (B,), 0, npg * ps, jnp.int32)
    out = paged_decode_attention(q, kp, vp, pt, idx, window=window,
                                 interpret=True)
    exp = dref.paged_decode_attention_ref(q, kp, vp, pt, idx, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


def test_layers_decode_routes_through_kernels():
    """layers.attention_decode / attention_decode_paged with use_pallas route
    through the flash-decode kernels and match their einsum fallbacks."""
    from repro.models.layers import (ModelOptions, attention_decode,
                                     attention_decode_paged)
    opts = ModelOptions(use_pallas=True, pallas_interpret=True)
    B, S, N, K, h, ps = 2, 128, 4, 2, 32, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, 1, N, h))
    kc = jax.random.normal(ks[1], (B, S, K, h))
    vc = jax.random.normal(ks[2], (B, S, K, h))
    idx = jnp.asarray([100, 7], jnp.int32)
    out = attention_decode(q, kc, vc, idx, window=0, opts=opts)
    exp = attention_decode(q, kc, vc, idx, window=0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)
    npg = S // ps
    kp = kc.reshape(B * npg, ps, K, h)
    vp = vc.reshape(B * npg, ps, K, h)
    pt = jnp.arange(B * npg, dtype=jnp.int32).reshape(B, npg)
    out_p = attention_decode_paged(q, kp, vp, pt, idx, window=0, opts=opts)
    exp_p = attention_decode_paged(q, kp, vp, pt, idx, window=0)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(exp_p),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


def test_paged_matches_dense_layout():
    """A paged cache whose table is the identity over contiguous pages is
    exactly the dense cache: both kernels and both oracles must agree."""
    B, S, N, K, h, ps = 2, 256, 4, 2, 32, 32
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, N, h))
    kc = jax.random.normal(ks[1], (B, S, K, h))
    vc = jax.random.normal(ks[2], (B, S, K, h))
    npg = S // ps
    kp = kc.reshape(B * npg, ps, K, h)
    vp = vc.reshape(B * npg, ps, K, h)
    pt = jnp.arange(B * npg, dtype=jnp.int32).reshape(B, npg)
    idx = jnp.asarray([200, 31], jnp.int32)
    dense = decode_attention(q, kc, vc, idx, bk=128, interpret=True)
    paged = paged_decode_attention(q, kp, vp, pt, idx, interpret=True)
    exp = dref.decode_attention_ref(q, kc, vc, idx)
    np.testing.assert_allclose(np.asarray(paged), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,S,L,N,K,h,bk", [
    (2, 16, 256, 8, 2, 64, 128),   # chunk smaller than one band
    (1, 37, 200, 4, 4, 32, 64),    # odd chunk, L % bk != 0 (masked OOB tail)
    (2, 8, 96, 6, 2, 32, 32),      # several bands
    (1, 5, 64, 4, 1, 64, 128),     # bk > L: single clamped block
])
@pytest.mark.parametrize("window", [0, 48])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_chunk_prefill_attention(B, S, L, N, K, h, bk, window, dtype):
    """Banded chunk-prefill kernel vs the dense-softmax oracle, with
    per-slot start positions straddling band boundaries."""
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, S, N, h), dtype)
    kc = jax.random.normal(ks[1], (B, L, K, h), dtype)
    vc = jax.random.normal(ks[2], (B, L, K, h), dtype)
    idx = jax.random.randint(ks[3], (B,), 0, L - S, jnp.int32)
    out = chunk_prefill_attention(q, kc, vc, idx, window=window, bk=bk,
                                  interpret=True)
    exp = cref.chunk_prefill_ref(q, kc, vc, idx, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **_tol(dtype))


@pytest.mark.parametrize("start", [0, 15, 16, 17, 31, 47])
def test_chunk_prefill_band_boundaries(start):
    """Sweep the chunk start across band-boundary straddles: the first,
    middle, and last rows of the chunk land in different key blocks."""
    B, S, L, N, K, h, bk = 1, 9, 64, 4, 2, 32, 16
    ks = jax.random.split(KEY, 3)
    q = jax.random.normal(ks[0], (B, S, N, h))
    kc = jax.random.normal(ks[1], (B, L, K, h))
    vc = jax.random.normal(ks[2], (B, L, K, h))
    out = chunk_prefill_attention(q, kc, vc, start, bk=bk, interpret=True)
    exp = cref.chunk_prefill_ref(q, kc, vc, start)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("B,S,npg,ps,N,K,h", [
    (2, 16, 8, 16, 8, 2, 64), (1, 7, 6, 8, 4, 4, 32), (3, 4, 4, 32, 6, 1, 32),
])
@pytest.mark.parametrize("window", [0, 40])
def test_paged_chunk_prefill_attention(B, S, npg, ps, N, K, h, window):
    """Paged chunk-prefill kernel (page-table gather in the index map, no
    host-side pool gather) vs the gather-then-dense oracle, scrambled
    physical page order."""
    P = B * npg + 3
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, S, N, h))
    kp = jax.random.normal(ks[1], (P, ps, K, h))
    vp = jax.random.normal(ks[2], (P, ps, K, h))
    perm = jax.random.permutation(ks[3], jnp.arange(1, P))[:B * npg]
    pt = perm.reshape(B, npg).astype(jnp.int32)
    idx = jax.random.randint(ks[3], (B,), 0, npg * ps - S, jnp.int32)
    out = paged_chunk_prefill_attention(q, kp, vp, pt, idx, window=window,
                                        interpret=True)
    exp = cref.paged_chunk_prefill_ref(q, kp, vp, pt, idx, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=2e-5, rtol=2e-5)


@pytest.mark.parametrize("kv_dtype", [jnp.int8, jnp.float8_e4m3fn])
def test_paged_chunk_prefill_quantized(kv_dtype):
    """Quantized paged chunk kernel: codes + per-page-per-head scales
    gathered through the page table, dequantized in the VMEM tile."""
    from repro.models import kv_quant
    B, S, npg, ps, N, K, h = 2, 8, 6, 16, 4, 2, 64
    P = B * npg + 2
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, S, N, h))
    kp_f = jax.random.normal(ks[1], (P, ps, K, h))
    vp_f = jax.random.normal(ks[2], (P, ps, K, h))
    perm = jax.random.permutation(ks[3], jnp.arange(1, P))[:B * npg]
    pt = perm.reshape(B, npg).astype(jnp.int32)
    idx = jnp.asarray([3, 70], jnp.int32)
    kq, ksc = kv_quant.quantize_page_rows(kp_f, kv_dtype)
    vq, vsc = kv_quant.quantize_page_rows(vp_f, kv_dtype)
    out = paged_chunk_prefill_attention(q, kq, vq, pt, idx, k_scales=ksc,
                                        v_scales=vsc, interpret=True)
    exp = cref.paged_chunk_prefill_ref(q, kq, vq, pt, idx, k_scales=ksc,
                                       v_scales=vsc)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-4, rtol=1e-4)
    full = cref.paged_chunk_prefill_ref(q, kp_f, vp_f, pt, idx)
    err = float(jnp.abs(out - full).max())
    budget = 0.05 if kv_dtype == jnp.int8 else 0.2
    assert err < budget, f"quantization error {err} above {budget}"


@pytest.mark.parametrize("B,S,H,P,N,Q", [
    (2, 256, 4, 64, 128, 128), (1, 128, 2, 32, 64, 64),
    (2, 512, 3, 64, 128, 128), (1, 256, 8, 16, 32, 64),
])
def test_ssd_vs_sequential(B, S, H, P, N, Q):
    ks = jax.random.split(KEY, 5)
    xs = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A_log = jax.random.uniform(ks[2], (H,), minval=0.0, maxval=1.5)
    B_ = 0.3 * jax.random.normal(ks[3], (B, S, 1, N), jnp.float32)
    C_ = 0.3 * jax.random.normal(ks[4], (B, S, 1, N), jnp.float32)
    y, st = ssd(xs, dt, A_log, B_, C_, Q=Q, interpret=True)
    y_ref, st_ref = sref.ssd_scan_ref(xs, dt, A_log, B_, C_)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref),
                               atol=5e-4, rtol=5e-3)
    np.testing.assert_allclose(np.asarray(st), np.asarray(st_ref),
                               atol=5e-4, rtol=5e-3)


def test_ssd_chunked_matches_kernel():
    """The XLA fallback (ssd_chunked) and the Pallas kernel agree."""
    ks = jax.random.split(KEY, 5)
    B, S, H, P, N = 2, 256, 4, 32, 64
    xs = jax.random.normal(ks[0], (B, S, H, P), jnp.float32)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A_log = jax.random.uniform(ks[2], (H,), minval=0.0, maxval=1.5)
    B_ = 0.3 * jax.random.normal(ks[3], (B, S, 1, N))
    C_ = 0.3 * jax.random.normal(ks[4], (B, S, 1, N))
    y1, s1 = ssd(xs, dt, A_log, B_, C_, interpret=True)
    y2, s2 = sref.ssd_chunked(xs, dt, A_log, B_, C_)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               atol=2e-4, rtol=2e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               atol=2e-4, rtol=2e-3)


@pytest.mark.parametrize("E,C,D,F,act", [
    (4, 128, 256, 512, "silu"), (8, 64, 128, 96, "gelu"),
    (2, 256, 64, 128, "gelu_plain"), (16, 32, 64, 64, "silu"),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_gmm(E, C, D, F, act, dtype):
    ks = jax.random.split(KEY, 4)
    xe = 0.3 * jax.random.normal(ks[0], (E, C, D), dtype)
    wi = 0.3 * jax.random.normal(ks[1], (E, D, F), dtype)
    wg = 0.3 * jax.random.normal(ks[2], (E, D, F), dtype)
    wo = 0.3 * jax.random.normal(ks[3], (E, F, D), dtype)
    out = grouped_mlp(xe, wi, wg, wo, act, interpret=True)
    exp = np.asarray(gref.grouped_mlp_ref(xe, wi, wg, wo, act), np.float32)
    # bf16: the intermediate h is quantized in both kernel and ref; error
    # scales with output magnitude (two D/F-deep accumulations), so atol
    # scales with max|exp| (~bf16 eps of the output scale)
    tol = _tol(dtype)
    if dtype == jnp.bfloat16:
        tol = dict(atol=0.02 * float(np.abs(exp).max()) + 1e-3, rtol=5e-2)
    np.testing.assert_allclose(np.asarray(out, np.float32), exp, **tol)


@pytest.mark.parametrize("window", [0, 40])
@pytest.mark.parametrize("kv_dtype", [jnp.int8, jnp.float8_e4m3fn])
def test_paged_decode_attention_quantized(window, kv_dtype):
    """Quantized paged kernel: int8/fp8 pages + per-page-per-head scales
    gathered through the page table, dequantized in the VMEM tile. Must
    match the dequantize-then-dense oracle to fp32 accumulate precision,
    and stay close to the unquantized fp32 attention."""
    from repro.models import kv_quant
    B, npg, ps, N, K, h = 3, 8, 16, 8, 2, 64
    P = B * npg + 3
    ks = jax.random.split(KEY, 4)
    q = jax.random.normal(ks[0], (B, N, h))
    kp_f = jax.random.normal(ks[1], (P, ps, K, h))
    vp_f = jax.random.normal(ks[2], (P, ps, K, h))
    perm = jax.random.permutation(ks[3], jnp.arange(1, P))[:B * npg]
    pt = perm.reshape(B, npg).astype(jnp.int32)
    idx = jax.random.randint(ks[3], (B,), 0, npg * ps, jnp.int32)
    kq, ksc = kv_quant.quantize_page_rows(kp_f, kv_dtype)
    vq, vsc = kv_quant.quantize_page_rows(vp_f, kv_dtype)
    out = paged_decode_attention(q, kq, vq, pt, idx, k_scales=ksc,
                                 v_scales=vsc, window=window, interpret=True)
    exp = dref.paged_decode_attention_quant_ref(q, kq, vq, ksc, vsc, pt, idx,
                                                window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-4, rtol=1e-4)
    full = dref.paged_decode_attention_ref(q, kp_f, vp_f, pt, idx,
                                           window=window)
    err = float(jnp.abs(out - full).max())
    budget = 0.05 if kv_dtype == jnp.int8 else 0.2
    assert err < budget, f"quantization error {err} above {budget}"


def test_quantize_page_rows_roundtrip():
    """encode/decode invariants the monotone-amax write policy relies on:
    dequantized values are within half a code of the input, all-zero pages
    get scale 0 and decode to exactly 0, and encode(decode(c)) == c at a
    fixed scale (drift-free rewrites)."""
    from repro.models import kv_quant
    rows = jax.random.normal(KEY, (5, 8, 2, 16)) * \
        jnp.asarray([0.1, 1.0, 10.0, 100.0, 0.0]).reshape(5, 1, 1, 1)
    for dt in (jnp.int8, jnp.float8_e4m3fn):
        codes, scales = kv_quant.quantize_page_rows(rows, dt)
        assert codes.dtype == dt and scales.shape == (5, 2)
        deq = kv_quant.decode(codes, scales[:, None, :, None])
        half_code = np.asarray(scales)[:, None, :, None] * \
            (0.51 if dt == jnp.int8 else 0.07 * kv_quant.qmax(dt))
        assert np.all(np.abs(np.asarray(deq - rows)) <= half_code + 1e-9)
        assert float(jnp.abs(deq[4]).max()) == 0.0      # zero page -> 0
        assert float(scales[4].max()) == 0.0
        again = kv_quant.encode(deq, scales[:, None, :, None], dt)
        assert jnp.array_equal(codes, again)
