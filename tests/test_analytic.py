"""The analytic cell-pricing model that backs §Roofline."""
import pytest

from repro.configs import SHAPES, get_config
from repro.distributed.sharding import DEFAULT_RULES, INFERENCE_RULES
from repro.roofline.analytic import (analytic_cell, kv_cache_bytes,
                                     params_bytes_per_dev)

MESH = {"pod": 1, "data": 16, "model": 16}


def test_params_bytes_sharding_sanity():
    # gemma: fully shardable -> close to total/256; smollm: heads/kv
    # replicate but big tensors (vocab, mlp) shard
    g = get_config("gemma3-27b")
    pb = params_bytes_per_dev(g, MESH)
    total = g.param_counts()["total"] * 2
    assert total / 256 * 0.8 < pb < total / 256 * 3
    s = get_config("smollm-135m")
    pbs = params_bytes_per_dev(s, MESH)
    assert pbs < s.param_counts()["total"] * 2 / 16  # at least data-sharded


def test_inference_rules_store_more_but_fit():
    g = get_config("gemma3-27b")
    fsdp = params_bytes_per_dev(g, MESH)
    infer = params_bytes_per_dev(g, MESH, rules=INFERENCE_RULES)
    assert infer > fsdp                  # replication costs storage...
    assert infer < 16e9                  # ...but still fits v5e HBM
    # arctic 480B: expert width picks up the freed data axis
    a = get_config("arctic-480b")
    assert params_bytes_per_dev(a, MESH, rules=INFERENCE_RULES) < 16e9


def test_window_cache_shrinks_kv_bytes():
    g = get_config("gemma3-27b")
    full = kv_cache_bytes(g, SHAPES["decode_32k"], MESH, window_cache=False)
    ring = kv_cache_bytes(g, SHAPES["decode_32k"], MESH, window_cache=True)
    assert ring < 0.4 * full             # 50/62 layers cache 1024 vs 32768


def test_decode_is_memory_or_collective_bound():
    """The paper's claim, as priced on the TPU target."""
    for arch in ("gemma3-27b", "granite-3-2b", "whisper-small"):
        c = analytic_cell(get_config(arch), SHAPES["decode_32k"])
        t_c = c.flops_per_dev / 197e12
        t_m = c.hbm_bytes_per_dev / 819e9
        assert t_m > 10 * t_c, arch      # intensity « ridge


def test_causal_pairs_reduces_flops():
    a = get_config("arctic-480b")
    base = analytic_cell(a, SHAPES["prefill_32k"])
    opt = analytic_cell(a, SHAPES["prefill_32k"], causal_pairs=True)
    assert opt.flops_per_dev < 0.75 * base.flops_per_dev


def test_seq_parallel_reduces_collectives():
    j = get_config("jamba-1.5-large-398b")
    base = analytic_cell(j, SHAPES["train_4k"])
    opt = analytic_cell(j, SHAPES["train_4k"], seq_parallel=True)
    assert opt.coll_bytes_per_dev < 0.8 * base.coll_bytes_per_dev
    assert opt.flops_per_dev == base.flops_per_dev


def test_expert_padding_shards_moe_compute():
    import dataclasses
    g = get_config("granite-moe-3b-a800m")
    gp = dataclasses.replace(g, num_experts_padded=48)
    base = analytic_cell(g, SHAPES["train_4k"])
    opt = analytic_cell(gp, SHAPES["train_4k"])
    assert opt.flops_per_dev < 0.7 * base.flops_per_dev


def test_remat_flops_multiplier():
    g = get_config("granite-3-2b")
    with_r = analytic_cell(g, SHAPES["train_4k"], remat=True)
    without = analytic_cell(g, SHAPES["train_4k"], remat=False)
    assert with_r.flops_per_dev / without.flops_per_dev == pytest.approx(
        4.0 / 3.0, rel=1e-6)


def test_multi_pod_shards_batch_further():
    g = get_config("gemma3-27b")
    sp = analytic_cell(g, SHAPES["train_4k"])
    mp = analytic_cell(g, SHAPES["train_4k"], multi_pod=True)
    assert mp.flops_per_dev == pytest.approx(sp.flops_per_dev / 2, rel=1e-3)
