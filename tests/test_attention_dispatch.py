"""Unified attention dispatch: routing decisions, the banded chunk core's
bit-stability contract, and chunk-prefill kernel equality at the layer
level. (Kernel-vs-oracle shape sweeps live in test_kernels.py.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import GLOBAL_WINDOW
from repro.models import layers as L
from repro.models.layers import ModelOptions

KEY = jax.random.PRNGKey(7)


# ---------------------------------------------------------------------------
# routing (pure decisions, the docs/architecture.md dispatch table)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode,layout,pallas,expect", [
    ("decode", "dense", False, "decode_dense"),
    ("decode", "dense", True, "decode_flash"),
    ("decode", "paged", False, "decode_paged_gather"),
    ("decode", "paged", True, "decode_paged_flash"),
    ("decode", "ring", False, "decode_ring"),
    ("decode", "ring", True, "decode_ring"),
    ("chunk", "dense", False, "chunk_banded"),
    ("chunk", "dense", True, "chunk_flash"),
    ("chunk", "paged", False, "chunk_banded_gather"),
    ("chunk", "paged", True, "chunk_paged_flash"),
])
def test_route_cache_modes(mode, layout, pallas, expect):
    opts = ModelOptions(use_pallas=pallas)
    route = L.attention_route(mode, layout, S=16, Skv=256,
                              window=GLOBAL_WINDOW, opts=opts)
    assert route == expect


def test_route_fresh_shape_gates():
    """Fresh mode keeps the flash kernel's S % 128 == 0 / self-attention
    tiling gate; chunk mode has no such gate (the generalization to padded
    bands)."""
    opts = ModelOptions(use_pallas=True)
    assert L.attention_route("fresh", "none", S=256, Skv=256,
                             window=GLOBAL_WINDOW, opts=opts) == "fresh_flash"
    # not a multiple of 128 -> dense fallback even under use_pallas
    assert L.attention_route("fresh", "none", S=100, Skv=100,
                             window=GLOBAL_WINDOW, opts=opts) == "fresh_dense"
    # cross-attention shapes (Sq != Skv) never take the flash kernel
    assert L.attention_route("cross", "none", S=128, Skv=128,
                             window=GLOBAL_WINDOW, opts=opts,
                             causal=False) == "fresh_dense"
    # causal but not self-attention (Sq != Skv): the flash tiling gate the
    # old _core enforced via q.shape[1] == S must still hold
    assert L.attention_route("fresh", "none", S=128, Skv=256,
                             window=GLOBAL_WINDOW, opts=opts) != "fresh_flash"
    # chunk mode routes to the chunk kernel at any chunk length
    assert L.attention_route("chunk", "dense", S=5, Skv=256,
                             window=GLOBAL_WINDOW, opts=opts) == "chunk_flash"


def test_route_fresh_core_selection():
    """Large fresh shapes pick banded/flash-ref exactly as the old _core
    if-ladder did."""
    opts = ModelOptions(use_pallas=False, dense_attn_threshold=256,
                        attn_chunk=512)
    assert L.attention_route("fresh", "none", S=128, Skv=128,
                             window=GLOBAL_WINDOW, opts=opts) == "fresh_dense"
    assert L.attention_route("fresh", "none", S=1024, Skv=1024,
                             window=GLOBAL_WINDOW,
                             opts=opts) == "fresh_flash_ref"
    assert L.attention_route("fresh", "none", S=1024, Skv=1024, window=64,
                             opts=opts) == "fresh_banded"


def test_run_core_rejects_unknown_route():
    q = jnp.zeros((1, 1, 2, 4))
    with pytest.raises(ValueError, match="unknown attention route"):
        L.run_attention_core("nope", q, q, q, opts=ModelOptions(), window=0)


# ---------------------------------------------------------------------------
# banded chunk core: bit-stability contract
# ---------------------------------------------------------------------------

def _qkv(B, S, N, K, h, L_):
    ks = jax.random.split(KEY, 3)
    return (jax.random.normal(ks[0], (B, S, N, h)),
            jax.random.normal(ks[1], (B, L_, K, h)),
            jax.random.normal(ks[2], (B, L_, K, h)))


def test_banded_chunk_matches_dense_softmax_oracle():
    from repro.kernels.chunk_prefill.ref import chunk_prefill_ref
    q, kc, vc = _qkv(2, 9, 4, 2, 16, 80)
    idx = jnp.asarray([11, 37], jnp.int32)
    for w in (GLOBAL_WINDOW, 20):
        out = L.attention_chunk_banded(q, kc, vc, idx, w, 32)
        exp = chunk_prefill_ref(q, kc, vc, idx, window=w)
        np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                                   atol=2e-5, rtol=2e-5)


def test_banded_chunk_view_length_invariance():
    """Trailing fully-masked key blocks are exact no-ops: any cache view
    covering the live prefix gives bit-identical results — the structural
    fact the scheduler's bit-equality gates stand on."""
    q, kc, vc = _qkv(1, 6, 4, 2, 16, 96)
    idx = jnp.asarray(20, jnp.int32)              # live prefix = 26
    ref = L.attention_chunk_banded(q, kc, vc, idx, GLOBAL_WINDOW, 16)
    for view in (32, 48, 96):                     # all cover live=26
        out = L.attention_chunk_banded(q, kc[:, :view], vc[:, :view], idx,
                                       GLOBAL_WINDOW, 16)
        assert jnp.array_equal(out, ref), f"view {view} changed the bits"


def test_banded_chunk_chunking_invariance():
    """Splitting a prompt into chunks reproduces the monolithic result
    bit-for-bit (same absolute key-block partition, per-row no-ops)."""
    S = 13
    q, kc, vc = _qkv(1, S, 4, 2, 16, 64)
    base = jnp.asarray(7, jnp.int32)
    mono = L.attention_chunk_banded(q, kc, vc, base, GLOBAL_WINDOW, 16)
    for split in (1, 4, 9):
        a = L.attention_chunk_banded(q[:, :split], kc, vc, base,
                                     GLOBAL_WINDOW, 16)
        b = L.attention_chunk_banded(q[:, split:], kc, vc, base + split,
                                     GLOBAL_WINDOW, 16)
        assert jnp.array_equal(jnp.concatenate([a, b], 1), mono), \
            f"split at {split} changed the bits"


def test_banded_chunk_garbage_past_live_is_masked():
    """Lanes past a query's position may hold stale garbage (recycled cache
    rows, padded pages) — they must contribute exact zeros."""
    q, kc, vc = _qkv(1, 4, 4, 2, 16, 64)
    idx = jnp.asarray(10, jnp.int32)
    ref = L.attention_chunk_banded(q, kc, vc, idx, GLOBAL_WINDOW, 16)
    poisoned_k = kc.at[:, 14:].set(1e6)           # past live prefix (14)
    poisoned_v = vc.at[:, 14:].set(-1e6)
    out = L.attention_chunk_banded(q, poisoned_k, poisoned_v, idx,
                                   GLOBAL_WINDOW, 16)
    assert jnp.array_equal(out, ref)


def test_band_len():
    assert L.band_len(1, 32, 256) == 32
    assert L.band_len(32, 32, 256) == 32
    assert L.band_len(33, 32, 256) == 64
    assert L.band_len(300, 32, 256) == 256
    assert L.band_len(40, 32, 48) == 48           # clamp beats rounding


# ---------------------------------------------------------------------------
# layer-level: the routed attention() agrees across cores and live bounds
# ---------------------------------------------------------------------------

def _layer_params(D, N, K, h, key):
    ks = jax.random.split(key, 4)
    s = 0.2
    return {"wq": s * jax.random.normal(ks[0], (D, N, h)),
            "wk": s * jax.random.normal(ks[1], (D, K, h)),
            "wv": s * jax.random.normal(ks[2], (D, K, h)),
            "wo": s * jax.random.normal(ks[3], (N, h, D))}


def _mini_cfg():
    from repro.configs import get_config
    return get_config("smollm-135m").reduced()


def test_attention_live_len_bound_is_bitwise_noop():
    """attention(live_len=...) slices the banded view; any bound covering
    the live prefix must give bit-identical output AND identical cache."""
    cfg = _mini_cfg()
    D, N, K, h = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = _layer_params(D, N, K, h, KEY)
    opts = ModelOptions(remat=False)
    B, S, smax, start = 1, 8, 64, 10
    x = jax.random.normal(KEY, (B, S, D))
    cache = (jax.random.normal(KEY, (B, smax, K, h)),
             jax.random.normal(KEY, (B, smax, K, h)))
    positions = jnp.broadcast_to(start + jnp.arange(S), (B, S))
    outs = []
    for live in (start + S, 48, None):
        o, nc = L.attention(p, x, cfg, opts, GLOBAL_WINDOW, positions,
                            cache=cache, cache_index=jnp.asarray(start),
                            live_len=live)
        outs.append((o, nc))
    for o, nc in outs[1:]:
        assert jnp.array_equal(o, outs[0][0])
        for a, b in zip(nc, outs[0][1]):
            assert jnp.array_equal(a, b)


def test_attention_chunk_kernel_matches_banded_fallback():
    """use_pallas routes chunk mode through the chunk-prefill kernel; it
    must agree with the banded fallback to fp32-accumulate precision, on
    both layouts."""
    cfg = _mini_cfg()
    D, N, K, h = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    p = _layer_params(D, N, K, h, KEY)
    ref_opts = ModelOptions(remat=False)
    ker_opts = ModelOptions(remat=False, use_pallas=True,
                            pallas_interpret=True)
    B, S, smax, start, ps = 1, 8, 64, 10, 8
    x = jax.random.normal(KEY, (B, S, D))
    cache = (jax.random.normal(KEY, (B, smax, K, h)),
             jax.random.normal(KEY, (B, smax, K, h)))
    positions = jnp.broadcast_to(start + jnp.arange(S), (B, S))
    o_ref, _ = L.attention(p, x, cfg, ref_opts, GLOBAL_WINDOW, positions,
                           cache=cache, cache_index=jnp.asarray(start))
    o_ker, _ = L.attention(p, x, cfg, ker_opts, GLOBAL_WINDOW, positions,
                           cache=cache, cache_index=jnp.asarray(start))
    np.testing.assert_allclose(np.asarray(o_ker), np.asarray(o_ref),
                               atol=2e-5, rtol=2e-5)
    # paged: identity page table over the same contiguous rows
    npg = smax // ps
    pages = (cache[0].reshape(npg, ps, K, h), cache[1].reshape(npg, ps, K, h))
    pt = jnp.arange(npg, dtype=jnp.int32)[None]
    o_pref, _ = L.attention(p, x, cfg, ref_opts, GLOBAL_WINDOW, positions,
                            cache=pages, cache_index=jnp.asarray(start),
                            page_table=pt)
    o_pker, _ = L.attention(p, x, cfg, ker_opts, GLOBAL_WINDOW, positions,
                            cache=pages, cache_index=jnp.asarray(start),
                            page_table=pt)
    np.testing.assert_allclose(np.asarray(o_pker), np.asarray(o_pref),
                               atol=2e-5, rtol=2e-5)
    # and the unquantized paged fallback is bit-identical to the dense one
    assert jnp.array_equal(o_pref, o_ref)
