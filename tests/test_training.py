"""Training substrate: optimizer math, convergence, microbatching,
gradient compression, z-loss."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.data import lm_batches
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.training import (AdamWConfig, TrainConfig, init_train_state,
                            lm_loss, make_train_step)
from repro.training.compress import compress_grads, init_error_state
from repro.training.optimizer import adamw_update, global_norm, init_opt_state, lr_at
from conftest import reduced_params


def test_lr_schedule():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100,
                      min_lr_ratio=0.1)
    assert float(lr_at(cfg, 0)) == 0.0
    assert abs(float(lr_at(cfg, 10)) - 1e-3) < 1e-9
    assert float(lr_at(cfg, 100)) == pytest.approx(1e-4, rel=1e-3)
    assert float(lr_at(cfg, 5)) == pytest.approx(5e-4, rel=1e-3)


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=200,
                      weight_decay=0.0, grad_clip=100.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = init_opt_state(cfg, params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, state, _ = adamw_update(cfg, grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_clip():
    cfg = AdamWConfig(grad_clip=1.0)
    g = {"w": jnp.full((4,), 100.0)}
    assert float(global_norm(g)) == pytest.approx(200.0)
    p = {"w": jnp.zeros(4)}
    s = init_opt_state(cfg, p)
    _, _, m = adamw_update(cfg, g, s, p)
    assert float(m["grad_norm"]) == pytest.approx(200.0)


def test_loss_decreases(key, opts):
    cfg, params = reduced_params("qwen1.5-0.5b")
    tcfg = TrainConfig(opt=AdamWConfig(lr=5e-3, warmup_steps=2,
                                       total_steps=30))
    step = jax.jit(make_train_step(cfg, opts, tcfg))
    state = init_train_state(cfg, tcfg, params)
    p = params
    losses = []
    for b in lm_batches(cfg, 8, 32, steps=10, seed=1):
        batch = {k: jnp.asarray(v) for k, v in b.items()}
        p, state, m = step(p, state, batch)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0]
    assert all(np.isfinite(losses))


def test_microbatching_matches_full_batch(key, opts):
    cfg, params = reduced_params("smollm-135m")
    tok = jax.random.randint(key, (4, 16), 0, cfg.vocab_size)
    batch = {"tokens": tok}
    t1 = TrainConfig(opt=AdamWConfig(), microbatches=1, z_loss=0.0)
    t2 = TrainConfig(opt=AdamWConfig(), microbatches=2, z_loss=0.0)
    s1 = init_train_state(cfg, t1, params)
    s2 = init_train_state(cfg, t2, params)
    p1, _, m1 = make_train_step(cfg, opts, t1)(params, s1, batch)
    p2, _, m2 = make_train_step(cfg, opts, t2)(params, s2, batch)
    # same data -> nearly identical update (fp32 mean-of-means == mean here
    # only when microbatch losses weight equally, which they do: equal sizes)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-4)
    d = max(jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), p1, p2)))
    assert d < 1e-4


def test_padding_masked_in_loss(opts, key):
    cfg, params = reduced_params("smollm-135m")
    tok = jax.random.randint(key, (2, 12), 0, cfg.vocab_size)
    padded = tok.at[:, 8:].set(-1)
    l1 = lm_loss(cfg, opts, params, {"tokens": padded})
    assert bool(jnp.isfinite(l1))


def test_compression_error_feedback():
    g = {"w": jnp.asarray(np.random.default_rng(0).normal(size=512)
                          .astype(np.float32))}
    e = init_error_state(g)
    total_dq = jnp.zeros_like(g["w"])
    for _ in range(20):
        dq, e = compress_grads(g, e)
        total_dq += dq["w"]
    # error feedback: accumulated dequantized grads converge to 20*g
    rel = float(jnp.abs(total_dq - 20 * g["w"]).max()
                / jnp.abs(g["w"]).max())
    assert rel < 0.05
