"""Serving engine: continuous batching correctness + VLA pipeline."""
import dataclasses
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.vla import vla_control_step
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.models.stacks import cache_batch_axis
from repro.serving import Request, ServingEngine
from repro.serving.engine import _scatter_slot
from repro.serving.sampler import greedy, sample
from conftest import reduced_params


@pytest.mark.slow
def test_engine_matches_single_stream(opts):
    cfg, params = reduced_params("qwen1.5-0.5b")
    eng = ServingEngine(cfg, opts, params, n_slots=3, max_seq=64, eos=-999)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
               for _ in range(5)]
    for i, pr in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=pr, max_tokens=6))
    done = eng.run()
    assert len(done) == 5
    by_uid = {r.uid: r for r in done}
    for uid, pr in enumerate(prompts):
        logits, caches = M.prefill(cfg, opts, params,
                                   {"tokens": jnp.asarray(pr[None])}, 64,
                                   cache_dtype=jnp.float32)
        toks = [int(greedy(logits)[0])]
        tok = jnp.asarray([[toks[0]]], jnp.int32)
        for i in range(len(by_uid[uid].out_tokens) - 1):
            logits, caches = M.decode_step(cfg, opts, params, tok, caches,
                                           len(pr) + i)
            t = int(greedy(logits)[0])
            toks.append(t)
            tok = jnp.asarray([[t]], jnp.int32)
        assert toks == by_uid[uid].out_tokens, f"request {uid} diverged"


def test_engine_more_requests_than_slots(opts):
    cfg, params = reduced_params("smollm-135m")
    eng = ServingEngine(cfg, opts, params, n_slots=2, max_seq=48, eos=-999)
    rng = np.random.default_rng(1)
    for i in range(6):
        eng.submit(Request(uid=i, prompt=rng.integers(
            0, cfg.vocab_size, 6, dtype=np.int32), max_tokens=4))
    done = eng.run()
    assert len(done) == 6
    assert all(len(r.out_tokens) == 4 for r in done)


def _streams(cfg, opts, params, reqs, *, fused, n_slots, max_seq, eos=-999,
             tick_tokens=4):
    """Run an engine over (prompt, max_tokens) pairs -> {uid: out_tokens}."""
    eng = ServingEngine(cfg, opts, params, n_slots=n_slots, max_seq=max_seq,
                        eos=eos, fused=fused, tick_tokens=tick_tokens)
    for i, (prompt, max_tokens) in enumerate(reqs):
        eng.submit(Request(uid=i, prompt=prompt.copy(),
                           max_tokens=max_tokens))
    done = eng.run()
    assert len(done) == len(reqs)
    return {r.uid: r.out_tokens for r in done}, eng


def test_fused_matches_reference_mixed_lengths(opts):
    """Token-for-token fused == reference across mixed prompt lengths, mixed
    budgets, and mid-stream admission (5 requests onto 2 slots, so slots
    free and refill at different ticks)."""
    cfg, params = reduced_params("qwen1.5-0.5b")
    rng = np.random.default_rng(2)
    reqs = [(rng.integers(0, cfg.vocab_size, l, dtype=np.int32), m)
            for l, m in [(4, 7), (9, 3), (6, 12), (3, 5), (8, 9)]]
    ref, _ = _streams(cfg, opts, params, reqs, fused=False, n_slots=2,
                      max_seq=64)
    fus, eng = _streams(cfg, opts, params, reqs, fused=True, n_slots=2,
                        max_seq=64)
    assert fus == ref
    assert all(len(fus[i]) == m for i, (_, m) in enumerate(reqs))
    assert eng.stats.decode_syncs < eng.stats.device_steps


def test_fused_eos_and_budget_termination(opts):
    """EOS mid-tick and budget exhaustion both terminate identically on the
    fused and reference paths."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(3)
    reqs = [(rng.integers(0, cfg.vocab_size, 6, dtype=np.int32), 8)]
    # budget termination first (eos that can never fire)
    ref, _ = _streams(cfg, opts, params, reqs, fused=False, n_slots=1,
                      max_seq=48)
    fus, _ = _streams(cfg, opts, params, reqs, fused=True, n_slots=1,
                      max_seq=48)
    assert fus == ref and len(fus[0]) == 8
    # now use a token the greedy stream actually emits mid-stream as EOS
    eos = ref[0][3]
    ref_e, _ = _streams(cfg, opts, params, reqs, fused=False, n_slots=1,
                        max_seq=48, eos=eos)
    fus_e, _ = _streams(cfg, opts, params, reqs, fused=True, n_slots=1,
                        max_seq=48, eos=eos)
    assert fus_e == ref_e
    assert fus_e[0][-1] == eos and len(fus_e[0]) < 8
    # prefill-emitted token counts against the budget / EOS too
    for fused in (False, True):
        one, _ = _streams(cfg, opts, params, [(reqs[0][0], 1)], fused=fused,
                          n_slots=1, max_seq=48)
        assert len(one[0]) == 1
    first_eos, _ = _streams(cfg, opts, params, reqs, fused=True, n_slots=1,
                            max_seq=48, eos=ref[0][0])
    assert first_eos[0] == [ref[0][0]]


def test_fused_host_sync_bound(opts):
    """The host-sync contract: ceil(N/K) decode syncs for an N-token decode
    on the fused path, N on the reference path."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(4)
    N, K = 10, 4
    reqs = [(rng.integers(0, cfg.vocab_size, 5, dtype=np.int32), N)]
    _, ref = _streams(cfg, opts, params, reqs, fused=False, n_slots=1,
                      max_seq=48, tick_tokens=K)
    _, fus = _streams(cfg, opts, params, reqs, fused=True, n_slots=1,
                      max_seq=48, tick_tokens=K)
    # N tokens = 1 from prefill + N-1 from the decode path
    assert ref.stats.decode_syncs == N - 1
    assert fus.stats.decode_syncs == math.ceil((N - 1) / K)
    assert fus.stats.tokens_decoded == ref.stats.tokens_decoded == N - 1


def test_scatter_slot_single_slot(opts):
    """n_slots == 1: slot and prefill caches have identical shapes, which
    broke the old first-mismatched-axis inference (StopIteration)."""
    cfg, params = reduced_params("qwen1.5-0.5b")
    rng = np.random.default_rng(5)
    reqs = [(rng.integers(0, cfg.vocab_size, 6, dtype=np.int32), 5)]
    fus, _ = _streams(cfg, opts, params, reqs, fused=True, n_slots=1,
                      max_seq=48)
    ref, _ = _streams(cfg, opts, params, reqs, fused=False, n_slots=1,
                      max_seq=48)
    assert fus == ref and len(fus[0]) == 5


def test_scatter_slot_batch_axis_annotation(opts):
    """_scatter_slot writes exactly the annotated batch slice of every cache
    leaf (block caches: axis 1 behind the stacked layer dim; tail: axis 0)."""
    cfg, _ = reduced_params("smollm-135m")
    big = M.init_caches(cfg, 3, 16, jnp.float32, opts)
    small = jax.tree.map(jnp.ones_like,
                         M.init_caches(cfg, 1, 16, jnp.float32, opts))
    out = _scatter_slot(big, small, 1)

    def check(path, leaf):
        ax = cache_batch_axis(path)
        by_slot = jnp.moveaxis(leaf, ax, 0)
        assert float(by_slot[1].min()) == 1.0, path
        assert float(by_slot[0].max()) == 0.0, path
        assert float(by_slot[2].max()) == 0.0, path

    jax.tree_util.tree_map_with_path(check, out)


def test_sampler_top_k(key):
    logits = jnp.asarray([[[0.0, 1.0, 2.0, 10.0]]])
    assert int(greedy(logits)[0]) == 3
    for seed in range(5):
        s = int(sample(logits, jax.random.PRNGKey(seed), temperature=1.0,
                       top_k=2)[0])
        assert s in (2, 3)


def test_vla_control_step_discrete(key):
    cfg, params = reduced_params("molmoact-7b")
    cfg2 = dataclasses.replace(cfg, n_cot_tokens=5, n_prompt_tokens=3)
    opts = ModelOptions(remat=False)
    batch = {"tokens": jnp.ones((2, 3), jnp.int32),
             "patches": 0.1 * jnp.ones((2, cfg.vision.num_tokens,
                                        cfg.vision.embed_dim))}
    out = vla_control_step(cfg2, opts, params, batch)
    assert out.cot_tokens.shape == (2, 5)
    assert out.action_tokens.shape == (2, cfg.action.num_action_tokens)
    assert out.trajectory is None


def test_vla_control_step_dit(key):
    cfg, params = reduced_params("molmoact-7b-dit")
    cfg2 = dataclasses.replace(cfg, n_cot_tokens=4, n_prompt_tokens=3)
    opts = ModelOptions(remat=False)
    batch = {"tokens": jnp.ones((1, 3), jnp.int32),
             "patches": 0.1 * jnp.ones((1, cfg.vision.num_tokens,
                                        cfg.vision.embed_dim))}
    out = vla_control_step(cfg2, opts, params, batch, key=key)
    assert out.trajectory.shape == (1, cfg.action.horizon,
                                    cfg.action.action_dim)
    assert bool(jnp.isfinite(out.trajectory).all())
