"""Serving engine: continuous batching correctness + VLA pipeline."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.vla import vla_control_step
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.serving import Request, ServingEngine
from repro.serving.sampler import greedy, sample
from conftest import reduced_params


def test_engine_matches_single_stream(opts):
    cfg, params = reduced_params("qwen1.5-0.5b")
    eng = ServingEngine(cfg, opts, params, n_slots=3, max_seq=64, eos=-999)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
               for _ in range(5)]
    for i, pr in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=pr, max_tokens=6))
    done = eng.run()
    assert len(done) == 5
    by_uid = {r.uid: r for r in done}
    for uid, pr in enumerate(prompts):
        logits, caches = M.prefill(cfg, opts, params,
                                   {"tokens": jnp.asarray(pr[None])}, 64,
                                   cache_dtype=jnp.float32)
        toks = [int(greedy(logits)[0])]
        tok = jnp.asarray([[toks[0]]], jnp.int32)
        for i in range(len(by_uid[uid].out_tokens) - 1):
            logits, caches = M.decode_step(cfg, opts, params, tok, caches,
                                           len(pr) + i)
            t = int(greedy(logits)[0])
            toks.append(t)
            tok = jnp.asarray([[t]], jnp.int32)
        assert toks == by_uid[uid].out_tokens, f"request {uid} diverged"


def test_engine_more_requests_than_slots(opts):
    cfg, params = reduced_params("smollm-135m")
    eng = ServingEngine(cfg, opts, params, n_slots=2, max_seq=48, eos=-999)
    rng = np.random.default_rng(1)
    for i in range(6):
        eng.submit(Request(uid=i, prompt=rng.integers(
            0, cfg.vocab_size, 6, dtype=np.int32), max_tokens=4))
    done = eng.run()
    assert len(done) == 6
    assert all(len(r.out_tokens) == 4 for r in done)


def test_sampler_top_k(key):
    logits = jnp.asarray([[[0.0, 1.0, 2.0, 10.0]]])
    assert int(greedy(logits)[0]) == 3
    for seed in range(5):
        s = int(sample(logits, jax.random.PRNGKey(seed), temperature=1.0,
                       top_k=2)[0])
        assert s in (2, 3)


def test_vla_control_step_discrete(key):
    cfg, params = reduced_params("molmoact-7b")
    cfg2 = dataclasses.replace(cfg, n_cot_tokens=5, n_prompt_tokens=3)
    opts = ModelOptions(remat=False)
    batch = {"tokens": jnp.ones((2, 3), jnp.int32),
             "patches": 0.1 * jnp.ones((2, cfg.vision.num_tokens,
                                        cfg.vision.embed_dim))}
    out = vla_control_step(cfg2, opts, params, batch)
    assert out.cot_tokens.shape == (2, 5)
    assert out.action_tokens.shape == (2, cfg.action.num_action_tokens)
    assert out.trajectory is None


def test_vla_control_step_dit(key):
    cfg, params = reduced_params("molmoact-7b-dit")
    cfg2 = dataclasses.replace(cfg, n_cot_tokens=4, n_prompt_tokens=3)
    opts = ModelOptions(remat=False)
    batch = {"tokens": jnp.ones((1, 3), jnp.int32),
             "patches": 0.1 * jnp.ones((1, cfg.vision.num_tokens,
                                        cfg.vision.embed_dim))}
    out = vla_control_step(cfg2, opts, params, batch, key=key)
    assert out.trajectory.shape == (1, cfg.action.horizon,
                                    cfg.action.action_dim)
    assert bool(jnp.isfinite(out.trajectory).all())
