"""The paper's simulator: validate every published claim + internal
consistency of the roofline machinery."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import claims
from repro.core.hardware import (CATALOG, ORIN, TABLE1, THOR, TPU_V5E,
                                 get_hardware)
from repro.core.scaling import scaled_vla, scaling_sweep
from repro.core.workload import build_vla_step, workload_totals
from repro.core.xpu_sim import simulate_phases, simulate_vla


@pytest.mark.parametrize("name", list(claims.ALL_CLAIMS))
def test_paper_claim(name):
    ok, measured, expect = claims.ALL_CLAIMS[name]()
    assert ok, f"{name}: measured {measured} vs expected {expect}"


def test_table1_catalog():
    assert len(TABLE1) == 7
    assert ORIN.mem_bw_gbs == 203 and ORIN.bf16_tflops == 100
    assert THOR.mem_bw_gbs == 273 and THOR.bf16_tflops == 500
    assert get_hardware("orin+pim").total_tflops == 1074
    assert get_hardware("thor+pim").total_tflops == 3993
    assert get_hardware("orin+gddr7").mem_bw_gbs == 1000


def test_prefetch_never_slower():
    """Cross-operator prefetch lower-bounds at max(sum_c, sum_m) <= sum(max)."""
    cfg = get_config("molmoact-7b")
    for hw in (ORIN, THOR, TPU_V5E):
        for p in simulate_vla(cfg, hw).phases:
            assert p.t_prefetch <= p.t_per_op + 1e-12


def test_decode_latency_scales_with_params():
    """Memory-bound decode: latency ~ active params / bw."""
    small = simulate_vla(get_config("smollm-135m"), ORIN)
    big = simulate_vla(get_config("gemma3-27b"), ORIN)
    r = (big.phase_seconds()["generation_decode"]
         / small.phase_seconds()["generation_decode"])
    n_ratio = (get_config("gemma3-27b").param_counts()["active"]
               / get_config("smollm-135m").param_counts()["active"])
    assert 0.3 * n_ratio < r < 3 * n_ratio


def test_moe_decode_cheaper_than_dense_equivalent():
    """MoE decode bytes ~ active params, not total."""
    moe = simulate_vla(get_config("granite-moe-3b-a800m"), ORIN)
    dense = simulate_vla(get_config("granite-3-2b"), ORIN)
    assert (moe.phase_seconds()["generation_decode"]
            < dense.phase_seconds()["generation_decode"])


def test_scaling_sweep_hits_targets():
    for cfg, target in zip(scaling_sweep((30e9, 100e9)), (30e9, 100e9)):
        n = cfg.param_counts()["total"]
        assert abs(n - target) / target < 0.25, (cfg.name, n)


def test_control_frequency_monotone_in_bandwidth():
    cfg = scaled_vla(30e9)
    freqs = [simulate_vla(cfg, get_hardware(h)).control_freq_hz
             for h in ("jetson-orin", "orin+lpddr5x", "orin+gddr7",
                       "orin+pim")]
    assert all(a < b for a, b in zip(freqs, freqs[1:])), freqs


def test_pim_routes_memory_bound_ops():
    cfg = get_config("molmoact-7b")
    rep = simulate_vla(cfg, get_hardware("orin+pim"))
    decode = [p for p in rep.phases if p.name == "generation_decode"][0]
    pim_ops = [o for o in decode.op_times if o.on_pim]
    assert pim_ops, "no ops routed to PIM"
    # compute-heavy prefill ops stay on SoC
    prefill = [p for p in rep.phases if p.name == "generation_prefill"][0]
    gemm_ops = [o for o in prefill.op_times if o.op.kind == "gemm"]
    assert all(not o.on_pim for o in gemm_ops)


def test_workload_totals_positive():
    for arch in ("molmoact-7b", "mamba2-780m", "whisper-small",
                 "jamba-1.5-large-398b"):
        t = workload_totals(build_vla_step(get_config(arch)))
        assert t["flops"] > 0 and t["bytes"] > 0


def test_vla_flops_roughly_2nd():
    """Decode-step FLOPs should be ~2*N_active per token."""
    cfg = get_config("molmoact-7b")
    phases = build_vla_step(cfg)
    dec = [p for p in phases if p.name == "generation_decode"][0]
    per_tok = sum(o.flops for o in dec.ops)
    n = cfg.param_counts()["active"]
    assert 1.5 * n < per_tok < 3.5 * n
