"""KV pool block allocator: refcounting, prefix cache, copy-on-write,
exhaustion — plus the paged serving engine end-to-end (paged decode must be
bit-identical to the dense layout under greedy sampling)."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving import KVPool, PoolExhausted, Request, ServingEngine
from conftest import reduced_params


# ---------------------------------------------------------------------------
# allocator unit tests
# ---------------------------------------------------------------------------

def _pool(num_pages=9, page_size=4, n_slots=2, pages_per_slot=4):
    return KVPool(num_pages, page_size, n_slots, pages_per_slot)


def test_admit_allocates_and_free_returns():
    p = _pool()
    pages, shared = p.admit(0, seq_len=10)       # ceil(10/4) = 3 pages
    assert len(pages) == 3 and shared == 0
    assert p.pages_in_use == 3
    assert 0 not in pages                        # null page never handed out
    assert list(p.page_table[0][:3]) == pages
    assert all(x == 0 for x in p.page_table[0][3:])
    p.free_slot(0)
    assert p.pages_in_use == 0
    assert np.all(p.page_table[0] == 0)


def test_prefix_cache_shares_full_pages():
    p = _pool()
    keys = [b"page0", b"page1"]
    a, shared_a = p.admit(0, seq_len=10, prefix_keys=keys)  # 2 full + 1 tail
    assert shared_a == 0
    b, shared_b = p.admit(1, seq_len=10, prefix_keys=keys)
    assert shared_b == 2 and p.prefix_hits == 2
    assert b[:2] == a[:2] and b[2] != a[2]       # tail page stays private
    assert p.refcount[a[0]] == 2
    # only 4 pages total despite 6 logical pages
    assert p.pages_in_use == 4


def test_prefix_cache_retains_freed_pages():
    """A hashed page whose refcount drops to zero is retained (LRU) and the
    next identical prompt still hits it."""
    p = _pool()
    keys = [b"k0"]
    a, _ = p.admit(0, seq_len=4, prefix_keys=keys)
    p.free_slot(0)
    assert p.pages_in_use == 0 and p.cached_pages == 1
    b, shared = p.admit(1, seq_len=4, prefix_keys=keys)
    assert shared == 1 and b[0] == a[0]
    assert p.cached_pages == 0                   # revived


def test_prefix_break_stops_sharing():
    """Sharing stops at the first non-matching page (the prefix property)."""
    p = _pool(num_pages=12)
    a, _ = p.admit(0, seq_len=12, prefix_keys=[b"x0", b"x1", b"x2"])
    b, shared = p.admit(1, seq_len=12, prefix_keys=[b"x0", b"DIFF", b"x2"])
    assert shared == 1
    assert b[0] == a[0] and b[1] != a[1] and b[2] != a[2]


def test_exhaustion_is_atomic_and_reclaims_cached():
    p = _pool(num_pages=5, pages_per_slot=4)     # 4 allocatable pages
    p.admit(0, seq_len=12)                       # 3 pages
    with pytest.raises(PoolExhausted):
        p.admit(1, seq_len=9)                    # needs 3, only 1 left
    assert p.pages_in_use == 3                   # rollback complete
    p.free_slot(0)
    # retained cache pages are reclaimed under pressure
    p2 = _pool(num_pages=4, pages_per_slot=3)
    p2.admit(0, seq_len=8, prefix_keys=[b"a", b"b"])
    p2.free_slot(0)
    assert p2.cached_pages == 2
    pages, _ = p2.admit(1, seq_len=12)           # needs all 3 pages
    assert len(pages) == 3 and p2.cached_pages == 0


def test_can_admit_agrees_with_admit_on_cached_shared_pages():
    """can_admit must not double-count prefix pages sitting in the retained
    cache (they are shared AND would otherwise look reclaimable): whenever
    can_admit says yes, admit must succeed."""
    p = _pool(num_pages=4, pages_per_slot=4)     # 3 allocatable, page_size 4
    keys = [b"p0", b"p1"]
    p.admit(0, seq_len=9, prefix_keys=keys)      # 2 hashed full + 1 partial
    p.free_slot(0)
    assert p.cached_pages == 2 and len(p._free) == 1
    # 13 positions sharing the 8-token prefix: 4 pages, 2 shared-from-cache
    # -> 2 fresh needed but only 1 truly allocatable
    assert not p.can_admit(13, keys)
    with pytest.raises(PoolExhausted):
        p.admit(1, seq_len=13, prefix_keys=keys)
    # and a request that does fit is still admissible
    assert p.can_admit(9, keys)
    pages, shared = p.admit(1, seq_len=9, prefix_keys=keys)
    assert shared == 2


def test_prepare_write_rolls_back_on_exhaustion():
    """A COW that runs out of pages mid-range must undo completed swaps —
    otherwise the caller never copies pages the table already points at."""
    p = _pool(num_pages=6, pages_per_slot=4)     # 5 allocatable
    a, _ = p.admit(0, seq_len=16)                # 4 pages
    p.fork(0, 1)                                 # all shared, 1 page left
    before = list(p.slot_pages[1])
    with pytest.raises(PoolExhausted):
        p.prepare_write(1, start=0, end=16)      # needs 4 copies, has 1
    assert p.slot_pages[1] == before             # fully rolled back
    assert list(p.page_table[1][:4]) == before
    assert all(p.refcount[pid] == 2 for pid in before)
    assert p.pages_in_use == 4


def test_fork_and_copy_on_write():
    p = _pool()
    a, _ = p.admit(0, seq_len=6)                 # 2 pages, tail partial
    p.fork(0, 1)
    assert p.slot_pages[1] == a
    assert p.refcount[a[1]] == 2
    # writing into the shared tail page must COW it
    copies = p.prepare_write(1, start=6, end=7)
    assert len(copies) == 1 and copies[0][0] == a[1]
    assert p.slot_pages[1][1] == copies[0][1] != a[1]
    assert p.refcount[a[1]] == 1                 # slot 0 owns it again
    assert p.page_table[1][1] == copies[0][1]
    # a second write to the now-private page needs no copy
    assert p.prepare_write(1, start=7, end=8) == []


def test_prepare_write_private_pages_noop():
    p = _pool()
    p.admit(0, seq_len=8)
    assert p.prepare_write(0, start=8, end=12) == []


def test_copy_pages_device_side():
    """The jitted COW page copy writes dst <- src on every paged leaf and
    leaves slot-batched leaves and other pages untouched."""
    from repro.models import model as M
    from repro.models.stacks import is_paged_leaf
    from repro.serving.engine import _copy_pages
    cfg, _ = reduced_params("smollm-135m")
    from repro.models.layers import ModelOptions
    caches = M.init_caches(cfg, 2, 32, jnp.float32,
                           ModelOptions(remat=False), paged=True,
                           num_pages=6, page_size=8)
    # fill each page p with the constant p
    caches = jax.tree_util.tree_map_with_path(
        lambda path, leaf: (leaf + jnp.arange(6).reshape(
            (1, 6, 1, 1, 1) if leaf.ndim == 5 else (6, 1, 1, 1)))
        if is_paged_leaf(path) else leaf, caches)
    src = jnp.asarray([3, 0, 0, 0], jnp.int32)
    dst = jnp.asarray([5, 0, 0, 0], jnp.int32)
    out = _copy_pages(caches, src, dst)

    def check(path, leaf):
        if not is_paged_leaf(path):
            return
        pages = leaf if leaf.ndim == 4 else leaf[0]
        assert float(pages[5].min()) == 3.0, path     # copied
        assert float(pages[3].min()) == 3.0, path     # source intact
        assert float(pages[1].max()) == 1.0, path     # others untouched
    jax.tree_util.tree_map_with_path(check, out)


# ---------------------------------------------------------------------------
# paged engine end-to-end
# ---------------------------------------------------------------------------

def _streams(cfg, opts, params, reqs, *, paged, fused=True, n_slots=2,
             max_seq=48, page_size=8, **kw):
    eng = ServingEngine(cfg, opts, params, n_slots=n_slots, max_seq=max_seq,
                        eos=-999, fused=fused, tick_tokens=4, paged=paged,
                        page_size=page_size, **kw)
    for i, (prompt, m) in enumerate(reqs):
        eng.submit(Request(uid=i, prompt=prompt.copy(), max_tokens=m))
    done = eng.run()
    assert len(done) == len(reqs)
    return {r.uid: r.out_tokens for r in done}, eng


def test_paged_matches_dense_mixed_lengths(opts):
    """Paged == dense token-for-token across mixed prompt lengths, budgets,
    and mid-stream admission, on both the fused and per-token paths."""
    cfg, params = reduced_params("qwen1.5-0.5b")
    rng = np.random.default_rng(7)
    reqs = [(rng.integers(0, cfg.vocab_size, l, dtype=np.int32), m)
            for l, m in [(4, 7), (9, 3), (6, 12), (3, 5), (8, 9)]]
    dense, _ = _streams(cfg, opts, params, reqs, paged=False)
    for fused in (True, False):
        paged, eng = _streams(cfg, opts, params, reqs, paged=True,
                              fused=fused)
        assert paged == dense, f"paged (fused={fused}) diverged from dense"
        assert eng.stats.pages_hwm > 0
        assert eng.stats.pages_in_use == 0       # all freed at drain


def test_paged_prefix_sharing_and_stats(opts):
    """Identical prompts share full prefix pages; EngineStats counts the
    hits and the high-water marks reflect sharing."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(8)
    prompt = rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)
    reqs = [(prompt, 5)] * 4
    dense, _ = _streams(cfg, opts, params, reqs, paged=False)
    paged, eng = _streams(cfg, opts, params, reqs, paged=True)
    assert paged == dense
    assert eng.stats.prefix_hits >= 3 * (16 // 8)   # 3 later reqs x 2 pages
    assert eng.stats.cache_bytes_hwm > 0
    by_uid = {r.uid: r for r in eng.finished}
    assert by_uid[0].pages_shared == 0
    assert all(by_uid[i].pages_shared == 2 for i in (1, 2, 3))


def test_paged_pool_exhaustion_defers_admission(opts):
    """An under-provisioned pool defers queued requests instead of crashing,
    and they complete once pages free up."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(9)
    reqs = [(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32), 6)
            for _ in range(4)]
    # 2 slots but pages for ~1.5 requests at a time
    paged, eng = _streams(cfg, opts, params, reqs, paged=True, num_pages=6)
    dense, _ = _streams(cfg, opts, params, reqs, paged=False)
    assert paged == dense
    assert eng.stats.pages_hwm <= 5


def test_paged_vision_prefix_keys(opts):
    """VLM requests hash patches into the prefix keys: identical
    (patches, prompt) pairs share pages; different patches must not."""
    cfg, params = reduced_params("molmoact-7b")
    rng = np.random.default_rng(10)
    prompt = rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
    px1 = 0.1 * rng.standard_normal(
        (cfg.vision.num_tokens, cfg.vision.embed_dim)).astype(np.float32)
    px2 = px1 + 0.5

    def run(patches_list):
        eng = ServingEngine(cfg, opts, params, n_slots=2, max_seq=48,
                            eos=-999, paged=True, page_size=8)
        for i, px in enumerate(patches_list):
            eng.submit(Request(uid=i, prompt=prompt.copy(), max_tokens=4,
                               patches=px))
        eng.run()
        return eng

    same = run([px1, px1])
    assert same.stats.prefix_hits > 0
    diff = run([px1, px2])
    assert diff.stats.prefix_hits == 0


def test_budget_clamped_to_cache_capacity(opts):
    """max_tokens overflowing max_seq is clamped (with a warning) instead of
    silently corrupting the cache — and both layouts clamp identically, so
    the bit-equality contract holds for over-budget requests too."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(12)
    reqs = [(rng.integers(0, cfg.vocab_size, 28, dtype=np.int32), 10)]
    outs = {}
    for paged in (False, True):
        with pytest.warns(RuntimeWarning, match="exceeds cache capacity"):
            outs[paged], _ = _streams(cfg, opts, params, reqs, paged=paged,
                                      n_slots=1, max_seq=32, page_size=8)
    assert outs[True] == outs[False]
    # prefill token + (max_seq - prompt_len) decode tokens
    assert len(outs[True][0]) == 1 + (32 - 28)


def test_paged_growth_preemption_under_pressure(opts):
    """When decode growth exhausts the pool, a victim slot is preempted and
    retried rather than crashing run(); greedy streams still match dense."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(13)
    reqs = [(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32), 17)
            for _ in range(2)]
    dense, _ = _streams(cfg, opts, params, reqs, paged=False, n_slots=2,
                        max_seq=32)
    # 5 allocatable pages, but both requests want 4 pages at full length
    paged, eng = _streams(cfg, opts, params, reqs, paged=True, n_slots=2,
                          max_seq=32, num_pages=6)
    assert paged == dense
    assert eng.stats.pages_hwm <= 5


def test_paged_request_that_never_fits_raises(opts):
    """A request needing more pages than the whole pool is a sizing error
    (raised), not a silent livelock of deferrals."""
    from repro.serving import PoolExhausted
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(14)
    eng = ServingEngine(cfg, opts, params, n_slots=2, max_seq=32, eos=-999,
                        paged=True, page_size=8, num_pages=3)
    eng.submit(Request(uid=0, prompt=rng.integers(
        0, cfg.vocab_size, 20, dtype=np.int32), max_tokens=4))
    with pytest.raises(PoolExhausted, match="too small"):
        eng.run()


def test_engine_pallas_kernel_path_matches_reference(opts):
    """With use_pallas the engine decodes through the flash-decode kernels
    (dense and paged, interpret mode); greedy streams must match the plain
    einsum engine."""
    from repro.models.layers import ModelOptions
    cfg, params = reduced_params("smollm-135m")
    popts = ModelOptions(remat=False, use_pallas=True, pallas_interpret=True)
    rng = np.random.default_rng(15)
    reqs = [(rng.integers(0, cfg.vocab_size, 9, dtype=np.int32), 4)
            for _ in range(2)]
    ref, _ = _streams(cfg, opts, params, reqs, paged=False, n_slots=1,
                      max_seq=32)
    for paged in (False, True):
        out, _ = _streams(cfg, popts, params, reqs, paged=paged, n_slots=1,
                          max_seq=32)
        assert out == ref, f"pallas engine path (paged={paged}) diverged"


def test_run_surfaces_exhausted_tick_budget(opts):
    """run(max_ticks) must warn and expose the pending count instead of
    silently returning with requests still queued/in flight."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(11)
    eng = ServingEngine(cfg, opts, params, n_slots=1, max_seq=48, eos=-999)
    for i in range(3):
        eng.submit(Request(uid=i, prompt=rng.integers(
            0, cfg.vocab_size, 6, dtype=np.int32), max_tokens=8))
    with pytest.warns(RuntimeWarning, match="tick budget"):
        done = eng.run(max_ticks=1)
    assert eng.pending == 3 - len(done) and eng.pending > 0
    # draining the rest clears the pending count, no warning
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        eng.run()
    assert eng.pending == 0


def test_paged_rejects_bad_geometry(opts):
    cfg, params = reduced_params("smollm-135m")
    with pytest.raises(ValueError, match="must divide"):
        ServingEngine(cfg, opts, params, max_seq=50, paged=True,
                      page_size=16)


# ---------------------------------------------------------------------------
# quantized pool (int8/fp8 pages + per-page scale siblings)
# ---------------------------------------------------------------------------

def test_quantized_requires_paged(opts):
    """kv_dtype quantization without the paged layout is a config error, at
    both the template and the engine boundary."""
    from repro.models import stacks
    cfg, params = reduced_params("smollm-135m")
    with pytest.raises(ValueError, match="requires the paged layout"):
        stacks.cache_template(cfg, 1, 32, kv_dtype="int8")
    with pytest.raises(ValueError, match="requires paged"):
        ServingEngine(cfg, opts, params, max_seq=32, kv_dtype="int8")
    with pytest.raises(ValueError, match="kv_dtype"):
        stacks.cache_template(cfg, 1, 32, paged=True, num_pages=4,
                              page_size=8, kv_dtype="int4")


def test_quantized_cache_leaves_and_dtypes(opts):
    """Quantized paged caches carry int8/fp8 K/V pool leaves with f32
    per-page-per-head scale siblings [num_pages, K]; bf16 mode has none."""
    from repro.models import model as M
    from repro.models.stacks import is_paged_leaf, is_scale_leaf
    cfg, _ = reduced_params("smollm-135m")
    for kv_dtype, want in (("int8", jnp.int8), ("fp8", jnp.float8_e4m3fn)):
        caches = M.init_caches(cfg, 2, 32, jnp.float32, opts, paged=True,
                               num_pages=6, page_size=8, kv_dtype=kv_dtype)
        n_scale = n_val = 0
        for path, leaf in jax.tree_util.tree_leaves_with_path(caches):
            if is_scale_leaf(path):
                n_scale += 1
                assert leaf.dtype == jnp.float32
                assert leaf.shape[-2:] == (6, cfg.num_kv_heads) or \
                    leaf.shape == (6, cfg.num_kv_heads)
            elif is_paged_leaf(path):
                n_val += 1
                assert leaf.dtype == want, path
        assert n_scale == n_val > 0
    plain = M.init_caches(cfg, 2, 32, jnp.float32, opts, paged=True,
                          num_pages=6, page_size=8)
    assert not any(is_scale_leaf(p) for p, _ in
                   jax.tree_util.tree_leaves_with_path(plain))


def test_quantized_streams_match_bf16(opts):
    """int8 greedy streams match the unquantized paged engine on both the
    fused and per-token paths; the quantized pool is smaller and keeps its
    prefix hits. (fp8 agreement is workload-dependent; gated in the bench.)"""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(21)
    shared = rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)
    reqs = [(shared, 6),
            (rng.integers(0, cfg.vocab_size, 9, dtype=np.int32), 7),
            (shared, 5)]
    ref, eng_ref = _streams(cfg, opts, params, reqs, paged=True)
    for fused in (True, False):
        out, eng = _streams(cfg, opts, params, reqs, paged=True, fused=fused,
                            kv_dtype="int8")
        assert out == ref, f"int8 (fused={fused}) diverged from bf16 paged"
        assert eng.stats.prefix_hits == eng_ref.stats.prefix_hits
        assert eng.stats.pages_hwm == eng_ref.stats.pages_hwm
        assert eng.stats.cache_bytes_hwm < 0.3 * eng_ref.stats.cache_bytes_hwm
        assert eng.stats.pages_in_use == 0


def test_quantized_pool_exhaustion_and_preemption(opts):
    """Scale rows ride the page lifecycle through deferral and preemption:
    an under-provisioned int8 pool defers/preempts and the reallocated pages
    (whose scale rows held stale values from the evicted request) are
    rewritten on re-scatter, so streams still match the roomy pool."""
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(22)
    reqs = [(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32), 17)
            for _ in range(2)]
    roomy, _ = _streams(cfg, opts, params, reqs, paged=True, n_slots=2,
                        max_seq=32, kv_dtype="int8")
    tight, eng = _streams(cfg, opts, params, reqs, paged=True, n_slots=2,
                          max_seq=32, num_pages=6, kv_dtype="int8")
    assert tight == roomy
    assert eng.stats.pages_hwm <= 5


def test_copy_pages_carries_scales():
    """The jitted COW page copy moves a page's scale row in lockstep with
    its values: after fork + prepare_write the copy must dequantize to the
    same numbers, even when the two pages' scales differ."""
    from repro.models import kv_quant
    from repro.models import model as M
    from repro.models.layers import ModelOptions
    from repro.models.stacks import is_paged_leaf, is_scale_leaf
    from repro.serving.engine import _copy_pages
    cfg, _ = reduced_params("smollm-135m")
    caches = M.init_caches(cfg, 2, 32, jnp.float32,
                           ModelOptions(remat=False), paged=True,
                           num_pages=6, page_size=8, kv_dtype="int8")
    # page p gets codes p and scale p/127 -> dequantized constant p*p/127
    caches = jax.tree_util.tree_map_with_path(
        lambda path, leaf:
        (leaf + (jnp.arange(6, dtype=jnp.float32) / 127.0).reshape(
            (1, 6, 1) if leaf.ndim == 3 else (6, 1)))
        if is_scale_leaf(path) else
        (leaf + jnp.arange(6, dtype=jnp.int8).reshape(
            (1, 6, 1, 1, 1) if leaf.ndim == 5 else (6, 1, 1, 1)))
        if is_paged_leaf(path) else leaf, caches)
    out = _copy_pages(caches, jnp.asarray([3, 0, 0, 0], jnp.int32),
                      jnp.asarray([5, 0, 0, 0], jnp.int32))

    def check(path, leaf):
        if is_scale_leaf(path):
            rows = leaf if leaf.ndim == 2 else leaf[0]
            np.testing.assert_allclose(np.asarray(rows[5]), 3 / 127.0,
                                       rtol=1e-6, err_msg=str(path))
            np.testing.assert_allclose(np.asarray(rows[1]), 1 / 127.0,
                                       rtol=1e-6, err_msg=str(path))
        elif is_paged_leaf(path):
            pages = leaf if leaf.ndim == 4 else leaf[0]
            assert int(pages[5].min()) == 3, path      # codes copied
            assert int(pages[3].min()) == 3, path      # source intact
            assert int(pages[1].max()) == 1, path      # others untouched
    jax.tree_util.tree_map_with_path(check, out)


def test_scatter_pages_quantizes_and_writes_scales(opts):
    """_scatter_pages encodes prefill KV into the int8 pool with
    amax-derived per-page-per-head scales: dequantized pages reconstruct the
    dense prefill rows, scales land only on the destination pages, and
    non-destination pages keep scale 0."""
    from repro.models import kv_quant
    from repro.models import model as M
    from repro.models.stacks import is_paged_leaf, is_scale_leaf
    from repro.serving.engine import _path_keys, _scatter_pages
    cfg, params = reduced_params("smollm-135m")
    ps, n_pages = 8, 6
    logits, cache1 = M.prefill(cfg, opts, params,
                               {"tokens": jnp.arange(16)[None]}, 16,
                               cache_dtype=jnp.float32)
    caches = M.init_caches(cfg, 1, 16, jnp.float32, opts, paged=True,
                           num_pages=n_pages, page_size=ps, kv_dtype="int8")
    dest = jnp.asarray([2, 4], jnp.int32)              # 16 tokens = 2 pages
    out = _scatter_pages(caches, cache1, dest, ps)
    flat1 = {_path_keys(p): l for p, l in
             jax.tree_util.tree_leaves_with_path(cache1)}
    checked = 0
    for path, leaf in jax.tree_util.tree_leaves_with_path(out):
        if not is_paged_leaf(path) or is_scale_leaf(path):
            continue
        keys = _path_keys(path)
        pages = leaf if leaf.ndim == 4 else leaf[0]    # [P, ps, K, h]
        scales = None
        for p2, l2 in jax.tree_util.tree_leaves_with_path(out):
            if _path_keys(p2) == keys[:-1] + (keys[-1] + "_scale",):
                scales = l2 if l2.ndim == 2 else l2[0]
        dense = flat1[keys]                            # [(nb,)1,S,K,h]
        dense = dense if dense.ndim == 4 else dense[0]
        rows = dense.reshape(2, ps, *dense.shape[2:])  # page-major
        for i, d in enumerate([2, 4]):
            deq = kv_quant.decode(pages[d], scales[d][None, :, None])
            np.testing.assert_allclose(np.asarray(deq), np.asarray(rows[i]),
                                       atol=float(scales[d].max()) * 0.51,
                                       err_msg=str(path))
        assert float(scales[1].max()) == 0.0           # non-dest untouched
        assert float(scales[5].max()) == 0.0
        checked += 1
    assert checked > 0


def test_update_cache_paged_quantized_monotone_scale():
    """Decode quantize-on-write: the page scale grows monotonically with the
    written token's amax, existing rows are requantized (not lost) when it
    grows, and a rewrite at an unchanged scale is drift-free."""
    from repro.models import kv_quant
    from repro.models.layers import update_cache_paged
    ps, K, h = 4, 2, 8
    pages = jnp.zeros((3, ps, K, h), jnp.int8)
    scales = jnp.zeros((3, K), jnp.float32)
    pt = jnp.asarray([[1, 2]], jnp.int32)
    small = jnp.full((1, 1, K, h), 0.5, jnp.float32)
    big = jnp.full((1, 1, K, h), 2.0, jnp.float32)
    pages, scales = update_cache_paged(pages, small, pt, 0, scales)
    s0 = np.asarray(scales[1]).copy()
    np.testing.assert_allclose(s0, 0.5 / 127.0, rtol=1e-6)
    pages, scales = update_cache_paged(pages, big, pt, 1, scales)
    np.testing.assert_allclose(np.asarray(scales[1]), 2.0 / 127.0, rtol=1e-6)
    # row 0 (written under the smaller scale) survived the requantization
    deq = kv_quant.decode(pages[1], np.asarray(scales[1])[None, :, None])
    np.testing.assert_allclose(np.asarray(deq[0]), 0.5, atol=2.0 / 127.0)
    np.testing.assert_allclose(np.asarray(deq[1]), 2.0, atol=2.0 / 127.0)
    # writing a smaller token later must not shrink the scale (monotone)...
    pages, scales = update_cache_paged(pages, small, pt, 2, scales)
    np.testing.assert_allclose(np.asarray(scales[1]), 2.0 / 127.0, rtol=1e-6)
    # ...and an identical rewrite is bit-stable (encode(decode(c)) == c)
    pages2, scales2 = update_cache_paged(pages, small, pt, 2, scales)
    assert jnp.array_equal(pages, pages2) and jnp.array_equal(scales, scales2)


def test_growth_pages_get_clean_scales(opts):
    """A page freed by one request and handed to another via decode growth
    must not leak its old scale into the new owner's quantize-on-write:
    streams from a pool with dirty history match a fresh pool's. Forced
    directly: poison every scale row, then check _ensure_pages growth resets
    exactly the grown pages' rows (COW-copied and held pages excluded)."""
    from repro.models.stacks import is_scale_leaf
    from repro.serving.engine import _reset_page_scales
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(23)
    prompt = rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)
    eng = ServingEngine(cfg, opts, params, n_slots=1, max_seq=32, eos=-999,
                        paged=True, page_size=8, kv_dtype="int8")
    eng.submit(Request(uid=0, prompt=prompt.copy(), max_tokens=10))
    eng._admit()
    held = list(eng.pool.slot_pages[0])
    # poison: pretend every page once belonged to a large-scale request
    eng.caches = jax.tree_util.tree_map_with_path(
        lambda path, leaf: leaf + 7.0 if is_scale_leaf(path) else leaf,
        eng.caches)
    eng._ensure_pages(eng.tick_tokens)
    grown = [p for p in eng.pool.slot_pages[0] if p not in held]
    assert grown, "test setup: tick must require page growth"
    for path, leaf in jax.tree_util.tree_leaves_with_path(eng.caches):
        if not is_scale_leaf(path):
            continue
        rows = leaf if leaf.ndim == 2 else leaf[0]
        for p in grown:
            assert float(jnp.abs(rows[p]).max()) == 0.0, (path, p)
        for p in held:
            assert float(rows[p].min()) >= 7.0, (path, p)  # held: untouched
    # and the unit helper resets only what it is told to
    again = _reset_page_scales(eng.caches, jnp.asarray(held[:1], jnp.int32))
    for path, leaf in jax.tree_util.tree_leaves_with_path(again):
        if is_scale_leaf(path):
            rows = leaf if leaf.ndim == 2 else leaf[0]
            assert float(jnp.abs(rows[held[0]]).max()) == 0.0


def test_quantized_null_page_stays_zero(opts):
    """Retired/empty slots riding a fused tick write into null page 0; the
    quantized write masks their codes and scale updates, so page 0 keeps
    its documented all-zero, scale-0 state (unit: a page-table row of zeros
    is a sink; e2e: an engine run with an idle slot leaves page 0 clean)."""
    from repro.models.layers import update_cache_paged
    from repro.models.stacks import is_paged_leaf, is_scale_leaf
    pages = jnp.zeros((3, 4, 2, 8), jnp.int8)
    scales = jnp.zeros((3, 2), jnp.float32)
    pt = jnp.asarray([[0, 0], [1, 2]], jnp.int32)     # slot 0 retired
    new = jnp.full((2, 1, 2, 8), 3.0, jnp.float32)
    pages, scales = update_cache_paged(pages, new, pt, jnp.asarray([5, 1]),
                                       scales)
    assert int(jnp.abs(pages[0]).max()) == 0 and float(scales[0].max()) == 0
    assert float(scales[1].max()) > 0                 # live slot wrote
    cfg, params = reduced_params("smollm-135m")
    rng = np.random.default_rng(24)
    reqs = [(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32), 6)]
    _, eng = _streams(cfg, opts, params, reqs, paged=True, n_slots=2,
                      kv_dtype="int8")
    for path, leaf in jax.tree_util.tree_leaves_with_path(eng.caches):
        if is_scale_leaf(path):
            p0 = leaf[:, 0] if leaf.ndim == 3 else leaf[0]
            assert float(jnp.abs(p0).max()) == 0.0, path
        elif is_paged_leaf(path):
            p0 = leaf[:, 0] if leaf.ndim == 5 else leaf[0]
            assert int(jnp.abs(p0.astype(jnp.int32)).max()) == 0, path


# ---------------------------------------------------------------------------
# decode-headroom reserve + chunk-granular prefix registration
# ---------------------------------------------------------------------------

def test_reserve_accounting_admission_vs_decode():
    """set_reserve fences the last pages off from admission-side allocation
    (admit / ensure(use_reserve=False)) while decode-side growth may still
    consume them — the pool-aware policy that keeps in-flight decodes from
    deadlocking behind fresh prompts."""
    p = _pool(num_pages=6, page_size=4, n_slots=2, pages_per_slot=5)  # 5 usable
    p.set_reserve(2)
    pages, _ = p.admit(0, seq_len=12)           # 3 pages: exactly the supply
    assert len(pages) == 3
    with pytest.raises(PoolExhausted):
        p.admit(1, seq_len=4)                   # admission blocked by reserve
    assert p.pages_in_use == 3                  # atomic: nothing leaked
    with pytest.raises(PoolExhausted):
        p.ensure(0, 16, use_reserve=False)      # prefill growth blocked too
    assert p.ensure(0, 16) and len(p.slot_pages[0]) == 4  # decode-side OK
    assert p.ensure(0, 20) and len(p.slot_pages[0]) == 5  # decode eats reserve
    with pytest.raises(PoolExhausted):
        p.ensure(1, 4)                          # genuinely empty now
    p.free_slot(0)
    assert p.pages_in_use == 0


def test_reserve_respected_by_can_admit():
    p = _pool(num_pages=6, page_size=4, n_slots=2, pages_per_slot=4)
    assert p.can_admit(16)                      # 4 pages of 5 usable
    p.set_reserve(2)
    assert not p.can_admit(16)                  # only 3 admissible now
    assert p.can_admit(12)
    with pytest.raises(ValueError):
        p.set_reserve(-1)
    with pytest.raises(ValueError):
        p.set_reserve(6)                        # > usable pages


def test_match_prefix_counts_leading_run():
    p = _pool()
    keys = [b"a", b"b", b"c"]
    assert p.match_prefix(keys) == 0
    p.admit(0, seq_len=8, prefix_keys=keys[:2])  # registers 2 full pages
    assert p.match_prefix(keys) == 2
    assert p.match_prefix([b"x", b"b"]) == 0     # prefix-closed: leading only


def test_admit_register_false_defers_registration():
    """Chunked admission must not register digests before the pages' KV is
    written: admit(register=False) leaves the prefix cache untouched and
    register_prefix_pages only registers pages the written span covers."""
    p = _pool()
    keys = [b"p0", b"p1"]
    pages, shared = p.admit(0, seq_len=10, prefix_keys=keys,
                            register=False)
    assert shared == 0 and p.match_prefix(keys) == 0
    assert p.register_prefix_pages(0, keys, n_written=5) == 1  # page 0 only
    assert p.match_prefix(keys) == 1
    assert p.register_prefix_pages(0, keys, n_written=10) == 1  # now page 1
    assert p.match_prefix(keys) == 2
    # idempotent, and never re-points an existing digest
    assert p.register_prefix_pages(0, keys, n_written=10) == 0
    b, shared_b = p.admit(1, seq_len=10, prefix_keys=keys)
    assert shared_b == 2 and b[:2] == pages[:2]
