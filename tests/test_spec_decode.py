"""Self-speculative decode: acceptance-rule units, spec-vs-reference
bit-equality across layouts/dtypes/depths, pool accounting, live-bound
normalization, and the front-end stats snapshot."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models.layers import ModelOptions, live_bound
from repro.serving import AsyncFrontend, Request, ServingEngine
from repro.serving.sampler import spec_accept
from conftest import reduced_params

ARCH = "smollm-135m"        # 4 reduced layers: draft depths 1..4


# -- spec_accept: the pure acceptance rule ---------------------------------

def _accept(draft, verify, eos=-999, budget=None, room=None, live=None):
    draft = jnp.asarray(draft, jnp.int32)
    verify = jnp.asarray(verify, jnp.int32)
    B, K = draft.shape
    budget = jnp.full((B,), 100, jnp.int32) if budget is None else \
        jnp.asarray(budget, jnp.int32)
    room = jnp.full((B,), 100, jnp.int32) if room is None else \
        jnp.asarray(room, jnp.int32)
    live = jnp.ones((B,), bool) if live is None else jnp.asarray(live, bool)
    n_emit, done = spec_accept(draft, verify, eos=eos, budget=budget,
                               room=room, live=live)
    return np.asarray(n_emit), np.asarray(done)


def test_accept_full_run_gets_bonus():
    # verify extends the fully-accepted draft: K-1 accepted + 1 bonus
    n, d = _accept([[5, 7, 9, 11]], [[7, 9, 11, 13]])
    assert n.tolist() == [4] and d.tolist() == [False]


def test_accept_first_mismatch_stops():
    # proposal 7 accepted, 8 != 9 rejected -> 1 accepted + bonus
    n, _ = _accept([[5, 7, 8, 11]], [[7, 9, 11, 13]])
    assert n.tolist() == [2]
    # immediate mismatch -> bonus token only (never less than 1)
    n, _ = _accept([[5, 0, 0, 0]], [[7, 9, 11, 13]])
    assert n.tolist() == [1]


def test_accept_no_resurrection_after_mismatch():
    # draft[3] "agrees" with verify[2] but sits after the first mismatch:
    # the cumulative prefix rule must not count it
    n, _ = _accept([[5, 7, 8, 11]], [[7, 9, 11, 13]])
    assert n.tolist() == [2]


def test_accept_eos_truncates_inside_run():
    # full agreement, but verify emits EOS at position 1: stop there
    n, d = _accept([[5, 7, -1, 11]], [[7, -1, 11, 13]], eos=-1)
    assert n.tolist() == [2] and d.tolist() == [True]


def test_accept_budget_and_room_cap():
    n, d = _accept([[5, 7, 9, 11]], [[7, 9, 11, 13]], budget=[2])
    assert n.tolist() == [2] and d.tolist() == [True]       # budget spent
    n, d = _accept([[5, 7, 9, 11]], [[7, 9, 11, 13]], room=[3])
    assert n.tolist() == [3] and d.tolist() == [False]      # tick quota
    n, _ = _accept([[5, 7, 9, 11]], [[7, 9, 11, 13]], budget=[1])
    assert n.tolist() == [1]


def test_accept_dead_slot_emits_nothing():
    n, d = _accept([[5, 7, 9, 11]], [[7, 9, 11, 13]], live=[False])
    assert n.tolist() == [0] and d.tolist() == [False]


def test_accept_k1_is_plain_decode():
    n, d = _accept([[5]], [[9]])
    assert n.tolist() == [1] and d.tolist() == [False]


def test_accept_batch_mixed():
    n, d = _accept([[5, 7, 9], [5, 0, 0], [5, 7, 9]],
                   [[7, 9, 11], [7, 9, 11], [7, -1, 11]],
                   eos=-1, budget=[100, 100, 100], live=[True, True, True])
    assert n.tolist() == [3, 1, 2]
    assert d.tolist() == [False, False, True]


def test_accept_property_invariants():
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=60, deadline=None)
    @given(st.integers(2, 6), st.data())
    def inner(K, data):
        B = 3
        draft = data.draw(st.lists(st.lists(st.integers(0, 3),
                                            min_size=K, max_size=K),
                                   min_size=B, max_size=B))
        verify = data.draw(st.lists(st.lists(st.integers(0, 3),
                                             min_size=K, max_size=K),
                                    min_size=B, max_size=B))
        budget = data.draw(st.lists(st.integers(1, K + 2),
                                    min_size=B, max_size=B))
        n, d = _accept(draft, verify, eos=0, budget=budget)
        for b in range(B):
            assert 1 <= n[b] <= min(K, budget[b])
            # emitted tokens are exactly the verifier's prefix, and every
            # non-final emitted token was an accepted proposal
            for j in range(1, n[b]):
                assert draft[b][j] == verify[b][j - 1]
            # no EOS strictly inside the emitted run
            assert 0 not in verify[b][:n[b] - 1]
            if verify[b][n[b] - 1] == 0 or budget[b] == n[b]:
                assert d[b]

    inner()


# -- spec engine ≡ plain fused engine (bit-equality) -----------------------

def _streams(cfg, opts, params, reqs, *, paged=False, kv_dtype="bf16",
             **kw):
    eng = ServingEngine(cfg, opts, params, n_slots=2, max_seq=64, eos=-999,
                        fused=True, tick_tokens=4, paged=paged, page_size=8,
                        kv_dtype=kv_dtype, **kw)
    for i, (p, m) in enumerate(reqs):
        eng.submit(Request(uid=i, prompt=p.copy(), max_tokens=m))
    done = eng.run()
    assert len(done) == len(reqs)
    return {r.uid: r.out_tokens for r in done}, eng


def _reqs(cfg, n=3):
    rng = np.random.default_rng(7)
    return [(rng.integers(0, cfg.vocab_size, int(rng.integers(5, 14)),
                          dtype=np.int32), int(rng.integers(4, 11)))
            for _ in range(n)]


_REF_CACHE = {}


def _reference(cfg, opts, params, reqs, paged, kv_dtype):
    # quantized references use the per-token scale layout the speculative
    # engines run on: bit-equality is a same-layout contract
    gran = {"scale_granularity": "token"} if kv_dtype != "bf16" else {}
    key = (paged, kv_dtype)
    if key not in _REF_CACHE:
        _REF_CACHE[key], _ = _streams(cfg, opts, params, reqs, paged=paged,
                                      kv_dtype=kv_dtype, **gran)
    return _REF_CACHE[key]


@pytest.mark.slow
@pytest.mark.parametrize("paged,kv_dtype,spec_k,draft_layers,draft_quant", [
    (False, "bf16", 1, 1, None),
    (False, "bf16", 2, 1, None),
    (False, "bf16", 4, 2, None),
    (False, "bf16", 8, 1, None),
    (True, "bf16", 2, 1, None),
    (True, "bf16", 4, 1, None),
    (True, "int8", 2, 1, None),
    (True, "int8", 8, 2, None),
    (True, "int8", 4, 4, "int8"),       # full-depth weight-quantized draft
])
def test_spec_matches_reference(opts, paged, kv_dtype, spec_k, draft_layers,
                                draft_quant):
    """The speculative stream must be bit-identical to the plain fused
    engine on the same layout — for every K, draft depth, cache layout and
    pool dtype, including a full-depth fake-quantized-weight draft (high
    acceptance, so the bonus/rollback edges all fire)."""
    cfg, params = reduced_params(ARCH)
    reqs = _reqs(cfg)
    ref = _reference(cfg, opts, params, reqs, paged, kv_dtype)
    got, eng = _streams(cfg, opts, params, reqs, paged=paged,
                        kv_dtype=kv_dtype, spec_decode=True, spec_k=spec_k,
                        draft_layers=draft_layers, draft_quant=draft_quant)
    assert got == ref, \
        f"spec stream diverged (K={spec_k}, draft={draft_layers})"
    ph = eng.stats.phase_report()
    if spec_k > 1:
        assert eng.stats.spec_verify_passes > 0
        assert ph["spec_accept_per_pass"] >= 1.0
        assert sum(ph["spec_accept_hist"][1:]) == eng.stats.spec_verify_passes
    # histogram mass = tokens emitted by spec ticks = everything except the
    # one token each request samples at prefill
    n_spec = sum(len(v) for v in got.values()) - len(reqs)
    assert sum(n * c for n, c in enumerate(ph.get("spec_accept_hist",
                                                  []))) == n_spec


def test_spec_pool_accounting_clean(opts):
    """Rejected draft rows must not leak pages: after a drain the pool is
    back to empty, and a second submit round on the same engine still runs
    (capacity was really returned, not just counted)."""
    cfg, params = reduced_params(ARCH)
    reqs = _reqs(cfg)
    got, eng = _streams(cfg, opts, params, reqs, paged=True, kv_dtype="int8",
                        spec_decode=True, spec_k=4, draft_layers=1)
    assert eng.pool.pages_in_use == 0
    for i, (p, m) in enumerate(reqs):
        eng.submit(Request(uid=100 + i, prompt=p.copy(), max_tokens=m))
    done = [r for r in eng.run() if r.uid >= 100]   # run() accumulates
    assert len(done) == len(reqs)
    assert {r.uid - 100: r.out_tokens for r in done} == got
    assert eng.pool.pages_in_use == 0


def test_spec_ctor_validation(opts):
    cfg, params = reduced_params(ARCH)
    with pytest.raises(ValueError, match="greedy"):
        ServingEngine(cfg, opts, params, n_slots=2, max_seq=32, eos=-1,
                      spec_decode=True, temperature=0.7)
    with pytest.raises(ValueError, match="fused"):
        ServingEngine(cfg, opts, params, n_slots=2, max_seq=32, eos=-1,
                      spec_decode=True, fused=False)
    with pytest.raises(ValueError, match="spec_k"):
        ServingEngine(cfg, opts, params, n_slots=2, max_seq=32, eos=-1,
                      spec_decode=True, spec_k=0)
    with pytest.raises(ValueError, match="draft_layers"):
        ServingEngine(cfg, opts, params, n_slots=2, max_seq=32, eos=-1,
                      spec_decode=True, draft_layers=99)
    # shared per-(page, head) scales cannot stay bit-equal under rollback
    with pytest.raises(ValueError, match="scale_granularity"):
        ServingEngine(cfg, opts, params, n_slots=2, max_seq=32, eos=-1,
                      spec_decode=True, paged=True, page_size=8,
                      kv_dtype="int8", scale_granularity="head")
    # ... and granularity is a quantized-pool knob only
    with pytest.raises(ValueError, match="quantized"):
        ServingEngine(cfg, opts, params, n_slots=2, max_seq=32, eos=-1,
                      scale_granularity="token")


def test_spec_int8_defaults_to_token_granularity(opts):
    cfg, params = reduced_params(ARCH)
    eng = ServingEngine(cfg, opts, params, n_slots=2, max_seq=32, eos=-1,
                        spec_decode=True, paged=True, page_size=8,
                        kv_dtype="int8")
    assert eng.scale_granularity == "token"
    # token-granularity scale leaves carry the page_size axis
    scale_ndims = {leaf.ndim for path, leaf in
                   jax.tree_util.tree_leaves_with_path(eng.caches)
                   if "scale" in str(path[-1])}
    assert scale_ndims and all(n >= 3 for n in scale_ndims)
    # a plain quantized engine keeps the compact per-(page, head) layout
    eng2 = ServingEngine(cfg, opts, params, n_slots=2, max_seq=32, eos=-1,
                         paged=True, page_size=8, kv_dtype="int8")
    assert eng2.scale_granularity == "head"


def test_spec_cancel_mid_round_frees_pool_and_keeps_survivors(opts):
    """Regression: ``cancel(uid)`` between ticks while speculative rounds
    are in flight must return the slot's pool pages (pool back to baseline
    after the drain) and must not disturb the surviving slot — its greedy
    stream stays bit-equal to a solo run of the same request."""
    cfg, params = reduced_params(ARCH)
    rng = np.random.default_rng(11)
    p0 = rng.integers(0, cfg.vocab_size, 12, dtype=np.int32)
    p1 = rng.integers(0, cfg.vocab_size, 9, dtype=np.int32)

    def make():
        return ServingEngine(cfg, opts, params, n_slots=2, max_seq=64,
                             eos=-999, fused=True, tick_tokens=4,
                             paged=True, page_size=8, spec_decode=True,
                             spec_k=4, draft_layers=1)

    ref_eng = make()
    ref_eng.submit(Request(uid=1, prompt=p1.copy(), max_tokens=20))
    ref = {r.uid: r.out_tokens for r in ref_eng.run()}[1]

    eng = make()
    assert eng.pool.pages_in_use == 0
    req0 = Request(uid=0, prompt=p0.copy(), max_tokens=24)
    eng.submit(req0)
    eng.submit(Request(uid=1, prompt=p1.copy(), max_tokens=20))
    for _ in range(3):              # both slots mid-decode, spec rounds run
        eng.step_fused()
    assert eng.stats.spec_verify_passes > 0
    assert all(eng.slots[s] is not None for s in range(2))
    assert eng.cancel(0), "uid 0 was not live anywhere"
    done = eng.run()
    assert {r.uid for r in done} == {1}, "cancelled request reached finished"
    assert {r.uid: r.out_tokens for r in done}[1] == ref, \
        "survivor's stream diverged after a mid-spec-round cancel"
    assert eng.pool.pages_in_use == 0, \
        "cancel leaked pool pages past the drain"
    assert req0.cancelled and not req0.done


# -- live_bound: per-slot bound normalization ------------------------------

def test_live_bound_forms():
    assert live_bound(None, 64) == 64
    assert live_bound(32, 64) == 32
    assert live_bound((16, 48, 8), 64) == 48
    assert live_bound([24], 64) == 24
    assert live_bound((), 64) == 64


# -- front-end stats snapshot ----------------------------------------------

def test_stats_snapshot_flat_json(opts):
    cfg, params = reduced_params(ARCH)
    eng = ServingEngine(cfg, opts, params, n_slots=2, max_seq=64, eos=-999,
                        fused=True, spec_decode=True, spec_k=2,
                        draft_layers=1)
    fe = AsyncFrontend([eng])
    snap = fe.stats_snapshot()        # safe before start(): gauges read 0
    assert json.loads(json.dumps(snap)) == snap
    assert all(isinstance(v, float) for v in snap.values())
    assert snap["replicas"] == 1.0
    assert snap["replica0_depth"] == 0.0
    assert "replica0_tick_ewma_s" in snap
