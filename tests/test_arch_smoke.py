"""Per-arch smoke tests (deliverable f): every assigned architecture at a
reduced config runs one forward pass and one train step on CPU with correct
shapes and no NaNs."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import list_archs
from repro.models import model as M
from repro.training import AdamWConfig, TrainConfig, init_train_state, make_train_step
from conftest import reduced_params

ARCHS = list(list_archs())


def _batch(cfg, key, B=2, S=16):
    batch = {"tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.encoder is not None:
        batch["frames"] = 0.1 * jax.random.normal(
            key, (B, cfg.encoder.num_tokens, cfg.encoder.embed_dim))
    if cfg.vision is not None:
        batch["patches"] = 0.1 * jax.random.normal(
            key, (B, cfg.vision.num_tokens, cfg.vision.embed_dim))
    return batch


@pytest.mark.parametrize("name", ARCHS)
def test_forward_smoke(name, key, opts):
    cfg, params = reduced_params(name)
    B, S = 2, 16
    batch = _batch(cfg, key, B, S)
    logits = M.forward(cfg, opts, params, batch)
    n_prefix = cfg.vision.num_tokens if cfg.vision is not None else 0
    assert logits.shape == (B, S + n_prefix, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), f"{name}: NaN/inf in logits"


@pytest.mark.parametrize("name", ARCHS)
def test_train_step_smoke(name, key, opts):
    cfg, params = reduced_params(name)
    tcfg = TrainConfig(opt=AdamWConfig(lr=1e-3, warmup_steps=1,
                                       total_steps=10))
    step = make_train_step(cfg, opts, tcfg)
    state = init_train_state(cfg, tcfg, params)
    batch = _batch(cfg, key)
    new_params, state, metrics = step(params, state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    # params actually changed
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         params, new_params)
    assert max(jax.tree.leaves(diffs)) > 0
