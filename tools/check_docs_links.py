#!/usr/bin/env python
"""Docs link check: every relative markdown link in README.md and docs/
must resolve to an existing file (anchors are stripped; external URLs and
badge/workflow links are skipped). Exits non-zero listing broken links —
run by CI so the docs tree cannot rot silently.

    python tools/check_docs_links.py [repo_root]
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def links_of(md: pathlib.Path):
    for target in LINK.findall(md.read_text()):
        if target.startswith(SKIP_PREFIXES):
            continue
        if target.startswith("../../"):
            continue  # repo-relative GitHub UI links (CI badge) — no file
        yield target.split("#", 1)[0]


def main() -> int:
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    files = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    broken = []
    checked = 0
    for md in files:
        if not md.exists():
            broken.append((md.relative_to(root), "<file missing>"))
            continue
        for target in links_of(md):
            checked += 1
            if not (md.parent / target).resolve().exists():
                broken.append((md.relative_to(root), target))
    for src, target in broken:
        print(f"BROKEN  {src}: {target}")
    print(f"checked {checked} relative links in {len(files)} files, "
          f"{len(broken)} broken")
    return 1 if broken else 0


if __name__ == "__main__":
    sys.exit(main())
