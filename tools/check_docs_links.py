#!/usr/bin/env python
"""Docs consistency check, run by CI so the docs tree cannot rot silently.

Two checks, both exiting non-zero with a listing on failure:

1. **Links.** Every relative markdown link in README.md and docs/ must
   resolve to an existing file (anchors are stripped; external URLs and
   badge/workflow links are skipped).
2. **Gate table.** The module keys in docs/benchmarks.md's gate table
   (the `| `key`` | ... |` rows of the "## Modules" section — other
   tables, e.g. the BENCH_*.json field schema, are not module
   registries) must exactly match the ``MODULES`` registry in
   benchmarks/run.py — a module added without a docs row (or a docs row
   for a renamed/removed module) fails. Parsed from source so the check
   needs no jax import.

    python tools/check_docs_links.py [repo_root]
"""
from __future__ import annotations

import pathlib
import re
import sys

LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")
TABLE_KEY = re.compile(r"^\|\s*`([a-z0-9_]+)`\s*\|", re.MULTILINE)
MODULE_KEY = re.compile(r"^\s*\"([a-z0-9_]+)\":\s*\w+,\s*$", re.MULTILINE)


def links_of(md: pathlib.Path):
    for target in LINK.findall(md.read_text()):
        if target.startswith(SKIP_PREFIXES):
            continue
        if target.startswith("../../"):
            continue  # repo-relative GitHub UI links (CI badge) — no file
        yield target.split("#", 1)[0]


def check_gate_table(root: pathlib.Path):
    """Module keys in the docs gate table vs benchmarks/run.py MODULES.
    Returns (problems, table_row_count)."""
    docs = root / "docs" / "benchmarks.md"
    runner = root / "benchmarks" / "run.py"
    problems = []
    if not docs.exists() or not runner.exists():
        missing = docs if not docs.exists() else runner
        return [(missing, "<file missing>")], 0
    text = docs.read_text()
    # scope to the "## Modules" section: later tables (BENCH field
    # schemas, per-gate detail tables) are not module registries
    start = text.find("## Modules")
    section = text[start:] if start >= 0 else text
    nxt = section.find("\n## ", 1)
    if nxt > 0:
        section = section[:nxt]
    table = set(TABLE_KEY.findall(section))
    src = runner.read_text()
    block = src[src.index("MODULES = {"):src.index("}", src.index("MODULES"))]
    modules = set(MODULE_KEY.findall(block))
    for key in sorted(modules - table):
        problems.append((docs.relative_to(root),
                         f"module `{key}` registered in benchmarks/run.py "
                         f"but missing from the gate table"))
    for key in sorted(table - modules):
        problems.append((docs.relative_to(root),
                         f"gate-table row `{key}` has no module in "
                         f"benchmarks/run.py"))
    return problems, len(table)


def main() -> int:
    root = pathlib.Path(sys.argv[1] if len(sys.argv) > 1 else ".").resolve()
    files = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    broken = []
    checked = 0
    for md in files:
        if not md.exists():
            broken.append((md.relative_to(root), "<file missing>"))
            continue
        for target in links_of(md):
            checked += 1
            if not (md.parent / target).resolve().exists():
                broken.append((md.relative_to(root), target))
    table_problems, n_rows = check_gate_table(root)
    for src, target in broken:
        print(f"BROKEN  {src}: {target}")
    for src, msg in table_problems:
        print(f"TABLE   {src}: {msg}")
    print(f"checked {checked} relative links in {len(files)} files and "
          f"{n_rows} gate-table rows; "
          f"{len(broken)} broken, {len(table_problems)} table mismatches")
    return 1 if broken or table_problems else 0


if __name__ == "__main__":
    sys.exit(main())
