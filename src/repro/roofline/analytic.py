"""Analytic per-cell cost model for the TPU roofline (§Roofline).

Why this exists: XLA's ``compiled.cost_analysis()`` counts a ``while`` body
ONCE, not x trip-count (verified in EXPERIMENTS.md §Dry-run), so HLO-raw
FLOPs/bytes undercount scanned models by ~num_layers. The roofline table is
therefore priced with the same operator-IR methodology as the paper's XPU
simulator — applied to our *actual lowered implementation* (baseline flash
computes full S^2 with masking; capacity-MoE reads every expert's weights;
remat recomputes the forward) — and validated against an *unrolled* compile
where XLA's counts are exact (see tests/test_roofline_validation.py).

Sharding awareness: per-op shard factors are derived from the same
divisibility rules the real shardings use (e.g. smollm's 9 heads do NOT
shard over model=16, so its attention FLOPs replicate — a real waste this
table surfaces).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional

import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig
from repro.core import workload as W
from repro.models import model as M
from repro.models.params import PSpec
from repro.distributed.sharding import DEFAULT_RULES, INFERENCE_RULES

BYTES = 2          # bf16
MOMENT_BYTES = 8   # fp32 mu+nu per param element... (4+4)


@dataclass
class CellCost:
    flops_per_dev: float
    hbm_bytes_per_dev: float
    coll_bytes_per_dev: float
    breakdown: Dict[str, float] = field(default_factory=dict)


def _divs(n: int, by: int) -> bool:
    return by > 0 and n % by == 0


def _mesh_sizes(multi_pod: bool):
    return {"pod": 2 if multi_pod else 1, "data": 16, "model": 16}


def params_bytes_per_dev(cfg: ModelConfig, mesh: Dict[str, int],
                         dtype_bytes: int = BYTES,
                         rules: Optional[dict] = None,
                         template: Optional[dict] = None) -> float:
    """Exact per-device parameter bytes under the logical-axis rules.
    ``template`` overrides the priced PSpec tree (e.g. the serving
    projection prices decoder/embed sharded but towers replicated)."""
    import jax
    rules = rules or DEFAULT_RULES
    if template is None:
        template = M.model_template(cfg)
    total = 0.0
    for leaf in jax.tree.leaves(template,
                                is_leaf=lambda x: isinstance(x, PSpec)):
        shard = 1
        used = set()
        for dim, ax in zip(leaf.shape, leaf.axes):
            phys = rules.get(ax) if ax else None
            if phys is None:
                continue
            phys_t = (phys,) if isinstance(phys, str) else tuple(phys)
            phys_t = tuple(a for a in phys_t if a in mesh and a not in used)
            while phys_t and dim % int(np.prod([mesh[a] for a in phys_t])):
                phys_t = phys_t[:-1]
            if phys_t:
                used.update(phys_t)
                shard *= int(np.prod([mesh[a] for a in phys_t]))
        total += float(np.prod(leaf.shape)) * dtype_bytes / shard
    return total


def _op_shard(cfg: ModelConfig, op: W.Op, mesh: Dict[str, int],
              batch_shardable: bool) -> float:
    """How many ways this op's FLOPs divide across the mesh."""
    model = mesh["model"]
    dp = mesh["pod"] * mesh["data"] if batch_shardable else 1
    n = op.name
    tp = 1
    if "/attn" in n or "/wq" in n or "/xq" in n or "/xattn" in n:
        tp = model if _divs(cfg.num_heads, model) else 1
    elif "/wkv" in n:
        tp = model if _divs(cfg.num_kv_heads, model) else 1
    elif "/wo" in n or "/xo" in n:
        tp = model if _divs(cfg.num_heads, model) else 1
    elif "/mlp" in n:
        tp = model if _divs(cfg.d_ff, model) else 1
    elif "/moe" in n:
        e_pad = max(cfg.num_experts_padded, cfg.num_experts)
        tp = model if _divs(e_pad, model) else 1
    elif "/router" in n:
        tp = 1
    elif "/ssm" in n or "/conv1d" in n or "/ssd" in n:
        d_in = cfg.ssm_expand * cfg.d_model
        tp = model if _divs(d_in, model) else 1
    elif "/lm_head" in n:
        tp = model if _divs(cfg.vocab_size, model) else 1
    elif "vision/" in n or "audio/" in n:
        enc = cfg.vision or cfg.encoder
        tp = model if enc and _divs(enc.num_heads, model) else 1
    return float(dp * tp)


def _fwd_ops(cfg: ModelConfig, shape: ShapeConfig, causal_half: bool):
    B = shape.global_batch
    S = shape.seq_len
    if shape.kind == "decode":
        ops = W.decoder_ops(cfg, B, 1, S, decode=True, tag="step")
    else:
        Stext = S
        ops = W.decoder_ops(cfg, B, Stext, Stext, decode=False, tag="step",
                            causal_half=causal_half)
        if cfg.vision is not None:
            ops += W.tower_ops(cfg, cfg.vision, B, "vision")
        if cfg.encoder is not None:
            ops += W.tower_ops(cfg, cfg.encoder, B, "audio")
    return ops


def kv_cache_bytes(cfg: ModelConfig, shape: ShapeConfig,
                   mesh: Dict[str, int], window_cache: bool = False) -> float:
    """Per-device KV/SSM cache bytes (read each decode step)."""
    model, dp = mesh["model"], mesh["pod"] * mesh["data"]
    B = shape.global_batch
    b_shard = dp if _divs(B, dp) else (mesh["data"] if _divs(B, mesh["data"]) else 1)
    total = 0.0
    for i in range(cfg.num_layers):
        if cfg.is_attn_layer(i):
            w = cfg.layer_window(i)
            seq = shape.seq_len
            if window_cache and w:
                seq = min(seq, w)
            kshard = model if _divs(cfg.num_kv_heads, model) else 1
            seq_shard = 1
            if b_shard == 1 and _divs(seq, mesh["data"]):
                seq_shard = mesh["data"]     # kv_seq sequence parallelism
            total += (B * seq * cfg.num_kv_heads * cfg.head_dim * 2 * BYTES
                      / (b_shard * kshard * seq_shard))
            if cfg.family == "encdec":
                total += (B * cfg.encoder.num_tokens * cfg.num_kv_heads
                          * cfg.head_dim * 2 * BYTES / (b_shard * kshard))
        elif cfg.family in ("ssm", "hybrid"):
            d_in = cfg.ssm_expand * cfg.d_model
            H = d_in // cfg.ssm_head_dim
            ishard = model if _divs(d_in, model) else 1
            total += (B * H * cfg.ssm_head_dim * cfg.ssm_state * 4
                      / (b_shard * 1)) \
                + B * (cfg.ssm_conv - 1) * (d_in + 2 * cfg.ssm_state) * BYTES \
                / (b_shard * ishard)
    return total


def analytic_cell(cfg: ModelConfig, shape: ShapeConfig, *,
                  multi_pod: bool = False, causal_pairs: bool = False,
                  window_cache: bool = False, remat: bool = True,
                  microbatches: int = 1, moe_gather_decode: bool = False,
                  infer_rules: bool = False, seq_parallel: bool = False,
                  moment_bytes: int = MOMENT_BYTES) -> CellCost:
    mesh = _mesh_sizes(multi_pod)
    chips = mesh["pod"] * mesh["data"] * mesh["model"]
    dp = mesh["pod"] * mesh["data"]
    B = shape.global_batch
    batch_shardable = _divs(B, dp) or _divs(B, mesh["data"])
    eff_dp = dp if _divs(B, dp) else (mesh["data"] if _divs(B, mesh["data"]) else 1)

    ops = _fwd_ops(cfg, shape, causal_half=causal_pairs)
    br: Dict[str, float] = {}

    # ---- FLOPs ----
    fwd_flops = 0.0
    for op in ops:
        shard = _op_shard(cfg, op, mesh, batch_shardable)
        if not batch_shardable and "attn" in op.name and shape.kind == "decode":
            # long-context decode: attention shards over kv_seq on 'data'
            shard *= mesh["data"]
        fwd_flops += op.flops / shard
    mult = 1.0
    if shape.kind == "train":
        mult = 3.0 + (1.0 if remat else 0.0)   # fwd + bwd(2x) + remat refwd
    flops = fwd_flops * mult
    br["flops_fwd"] = fwd_flops

    # ---- HBM bytes ----
    pb = params_bytes_per_dev(cfg, mesh)
    # per-step working weights: with FSDP rules every step must materialize
    # the data-gathered weights; with inference rules the full model-shard
    # lives in HBM and streams from there.
    pb_nofsdp = params_bytes_per_dev(cfg, mesh, rules=INFERENCE_RULES)
    if shape.kind != "train":
        pb_work = pb_nofsdp
    else:
        pb_work = pb
    act = sum(op.act_bytes / max(_op_shard(cfg, op, mesh, batch_shardable), 1)
              for op in ops)
    hbm = 0.0
    if shape.kind == "train":
        # weights: read fwd + bwd (+ remat refwd), per microbatch
        w_reads = (2.0 + (1.0 if remat else 0.0)) * microbatches
        hbm += pb * w_reads
        # optimizer: read+write params, grads, fp32 moments
        n_params_local = pb / BYTES
        hbm += n_params_local * (2 * BYTES + 2 * BYTES + 2 * moment_bytes)
        hbm += act * (2.0 + (1.0 if remat else 0.0))
        br["hbm_weights"] = pb * w_reads
        br["hbm_opt"] = n_params_local * (2 * BYTES + 2 * BYTES + 2 * moment_bytes)
        br["hbm_acts"] = act * (2.0 + (1.0 if remat else 0.0))
    elif shape.kind == "prefill":
        hbm += pb_work + act + kv_cache_bytes(cfg, shape, mesh, window_cache)
        br["hbm_weights"] = pb_work
        br["hbm_acts"] = act
    else:  # decode
        wb = pb_work
        if moe_gather_decode and cfg.num_experts:
            # only top-k experts' weights stream per token (gather path).
            # NOTE (§Perf): refuted under EP sharding — GSPMD lowers the
            # dynamic gather over the model-sharded expert dim as a weight
            # all-gather. This pricing is the shard_map-local ideal.
            counts = cfg.param_counts()
            moe_frac = counts["moe"] / max(counts["total"], 1.0)
            hit = W._expected_experts_hit(cfg.num_experts, cfg.top_k, B)
            wb = pb_work * (1.0 - moe_frac * (1.0 - hit / cfg.num_experts))
        cache = kv_cache_bytes(cfg, shape, mesh, window_cache)
        hbm += wb + cache + act
        br["hbm_weights"] = wb
        br["hbm_cache"] = cache
        br["hbm_acts"] = act

    # ---- collective bytes (per device, wire) ----
    coll = 0.0
    D = cfg.d_model
    b_loc = max(B / eff_dp, 1)
    s_new = 1 if shape.kind == "decode" else shape.seq_len
    tp_layers = sum(
        1 for i in range(cfg.num_layers)
        if (cfg.is_attn_layer(i) and _divs(cfg.num_heads, mesh["model"]))
        or (not cfg.is_attn_layer(i) and cfg.family in ("ssm", "hybrid")
            and _divs(cfg.ssm_expand * D, mesh["model"]))
        or (cfg.d_ff and _divs(cfg.d_ff, mesh["model"])))
    # sequence-parallel TP turns ARs into RS+AG: half the wire bytes
    ar = 1.0 if seq_parallel else 2.0
    fwd_bwd = 2.0 if shape.kind == "train" else 1.0
    coll += tp_layers * 2 * b_loc * s_new * D * BYTES * ar * fwd_bwd
    br["coll_tp"] = coll
    if cfg.num_experts and _divs(max(cfg.num_experts_padded, cfg.num_experts),
                                 mesh["model"]):
        # EP all-to-all exists only when experts actually shard over 'model'
        moe_layers = sum(1 for i in range(cfg.num_layers)
                         if cfg.is_moe_layer(i))
        a2a = 2 * moe_layers * cfg.top_k * b_loc * s_new * D * BYTES * fwd_bwd
        coll += a2a
        br["coll_ep_a2a"] = a2a
    if shape.kind != "train" and not infer_rules:
        # FSDP rules at inference: GSPMD all-gathers the data-sharded
        # weights when the batch is sharded (observed in the gemma
        # decode_32k HLO: 3.2 GB/step of weight AGs) but switches to
        # partial-sum activation all-reduces at batch=1 (observed in the
        # long_500k HLO: no weight AGs). Model follows the observed choice.
        weight_ag = max(pb_nofsdp - pb, 0.0)
        act_ar = cfg.num_layers * 2 * b_loc * s_new * D * BYTES * ar
        fsdp = weight_ag if batch_shardable else min(weight_ag, act_ar)
        coll += fsdp
        br["coll_fsdp_ag"] = fsdp
    if shape.kind == "train":
        # DP gradient all-reduce (+ hierarchical inter-pod stage) and FSDP
        # param all-gather / grad reduce-scatter over 'data'
        grad_sync = 2.0 * pb * (2.0 if multi_pod else 1.0)
        fsdp = 2.0 * pb * microbatches
        coll += grad_sync + fsdp
        br["coll_grad_sync"] = grad_sync
        br["coll_fsdp"] = fsdp

    return CellCost(flops, hbm, coll, br)
