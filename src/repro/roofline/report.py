"""Roofline terms from dry-run artifacts (EXPERIMENTS.md §Roofline).

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s
    memory term     = HLO_bytes_per_device / HBM_bw
    collective term = collective_bytes_per_device / link_bw

(cost_analysis() on the CPU backend reports *per-device* numbers, so the
per-chip formulation is used directly — equivalent to the global/chips one.)
Hardware constants: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link.
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass
from typing import Dict, List, Optional

import numpy as np

from repro.core.hardware import TPU_V5E


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    flops_per_dev: float
    bytes_per_dev: float
    coll_bytes_per_dev: float
    model_flops: float           # 6*N*D (dense) / 6*N_active*D (MoE)
    temp_bytes_per_dev: float = 0.0
    arg_bytes_per_dev: float = 0.0

    @property
    def t_compute(self) -> float:
        return self.flops_per_dev / (TPU_V5E.bf16_tflops * 1e12)

    @property
    def t_memory(self) -> float:
        return self.bytes_per_dev / (TPU_V5E.mem_bw_gbs * 1e9)

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_dev / (TPU_V5E.ici_gbs * 1e9)

    @property
    def dominant(self) -> str:
        t = {"compute": self.t_compute, "memory": self.t_memory,
             "collective": self.t_collective}
        return max(t, key=t.get)

    @property
    def bound_time(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / HLO_FLOPS (global) — remat/redundancy waste."""
        chips = 512 if self.mesh == "multi_pod" else 256
        hlo_global = self.flops_per_dev * chips
        return self.model_flops / max(hlo_global, 1.0)

    @property
    def roofline_fraction(self) -> float:
        """Useful-compute fraction of the roofline-bound step time."""
        chips = 512 if self.mesh == "multi_pod" else 256
        t_useful = self.model_flops / chips / (TPU_V5E.bf16_tflops * 1e12)
        return t_useful / max(self.bound_time, 1e-30)

    def row(self) -> Dict:
        d = asdict(self)
        d.update(t_compute=self.t_compute, t_memory=self.t_memory,
                 t_collective=self.t_collective, dominant=self.dominant,
                 useful_flops_ratio=self.useful_flops_ratio,
                 roofline_fraction=self.roofline_fraction)
        return d


def model_flops_for(cfg, shape) -> float:
    """6*N_active*D for training; 2*N_active*D for single forward/decode."""
    n = cfg.param_counts()["active"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    return 2.0 * n * shape.global_batch  # decode: one token per sequence


def load_artifacts(art_dir: str) -> List[Dict]:
    rows = []
    for f in sorted(os.listdir(art_dir)):
        if f.endswith(".json"):
            with open(os.path.join(art_dir, f)) as fh:
                rows.append(json.load(fh))
    return rows


def to_terms(row: Dict, use_analytic: bool = True) -> RooflineTerms:
    """Build roofline terms from a dry-run artifact.

    use_analytic=True (default) prices with the operator-IR model (see
    roofline/analytic.py) because XLA cost_analysis counts scan bodies once;
    False gives the HLO-raw numbers (cross-check / unrolled cells)."""
    an = row.get("analytic") if use_analytic else None
    if an:
        flops, bts, coll = (an["flops_per_dev"], an["hbm_bytes_per_dev"],
                            an["coll_bytes_per_dev"])
    else:
        flops = row["cost"].get("flops", 0.0)
        bts = row["cost"].get("bytes accessed", 0.0)
        coll = row["collectives"].get("total", 0.0)
    return RooflineTerms(
        arch=row["arch"], shape=row["shape"], mesh=row["mesh"],
        flops_per_dev=flops, bytes_per_dev=bts, coll_bytes_per_dev=coll,
        model_flops=row["model_flops"],
        temp_bytes_per_dev=row["memory"].get("temp_size_in_bytes", 0.0),
        arg_bytes_per_dev=row["memory"].get("argument_size_in_bytes", 0.0))


@dataclass
class ServingProjection:
    """Per-device view of a sharded serving engine (mesh shape in →
    per-device cache + weight bytes and the bandwidth-bound tick floor)."""
    arch: str
    mesh_model: int
    heads_sharded: bool          # serving rule table outcome (GQA-atomic)
    weight_bytes_per_dev: float
    cache_bytes_per_dev: float
    cache_bytes_total: float     # the engine's summed figure, for reference

    @property
    def t_tick_s(self) -> float:
        """Bandwidth-bound decode-tick floor: one full weight + live-cache
        HBM pass per decoded token (the paper's memory-bound action
        generation term), at the per-device slice sizes."""
        return ((self.weight_bytes_per_dev + self.cache_bytes_per_dev)
                / (TPU_V5E.mem_bw_gbs * 1e9))

    def row(self) -> Dict:
        d = asdict(self)
        d["t_tick_s"] = self.t_tick_s
        return d


def serving_projection(cfg, n_model: int, cache_bytes_total: float,
                       weight_dtype_bytes: int = 2) -> ServingProjection:
    """Project a single-device serving measurement onto a ``model=n_model``
    mesh, from the same rule table ``ServingEngine(mesh=...)`` shards with.

    ``cache_bytes_total`` is the engine's measured summed cache figure
    (``EngineStats.cache_bytes_hwm``). Every paged leaf — K/V pools and
    their scale siblings — carries the KV-head axis, so per-device cache
    bytes are exactly ``total / n_model`` when the serving rules shard the
    head axis and ``total`` when GQA-atomic divisibility forces the
    replication fallback (e.g. smollm's 9/3 heads over model=2). A sharded
    engine's ``cache_bytes_hwm_shard`` must reproduce this number; the
    ``sharded`` bench gates on it. Weights price through the analytic
    per-device pricer under the serving rules, with tower params (vision /
    action head) held replicated like the serving program keeps them.
    The 100B-scale projection is the same call with the big config and a
    measured-or-modelled cache total."""
    from repro.distributed.sharding import serving_rules
    from repro.models import model as M
    from repro.models.params import is_pspec
    from repro.roofline.analytic import params_bytes_per_dev
    rules = serving_rules(n_model, cfg.num_heads, cfg.num_kv_heads)
    heads_sharded = rules["kv_heads"] is not None and n_model > 1
    templ = M.model_template(cfg)
    towers = [templ.pop(k) for k in ("vision", "encoder", "action_dit")
              if k in templ]
    wb = params_bytes_per_dev(cfg, {"model": n_model}, weight_dtype_bytes,
                              rules, template=templ)
    import jax
    wb += sum(float(np.prod(leaf.shape)) * weight_dtype_bytes
              for t in towers
              for leaf in jax.tree_util.tree_leaves(t, is_leaf=is_pspec))
    return ServingProjection(
        arch=cfg.name, mesh_model=n_model, heads_sharded=heads_sharded,
        weight_bytes_per_dev=wb,
        cache_bytes_per_dev=float(cache_bytes_total)
        / (n_model if heads_sharded else 1),
        cache_bytes_total=float(cache_bytes_total))


def markdown_table(rows: List[RooflineTerms]) -> str:
    hdr = ("| arch | shape | mesh | t_compute | t_memory | t_collective | "
           "dominant | useful/HLO | roofline frac |\n"
           "|---|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r.arch} | {r.shape} | {r.mesh} | {r.t_compute:.3e}s "
            f"| {r.t_memory:.3e}s | {r.t_collective:.3e}s | {r.dominant} "
            f"| {r.useful_flops_ratio:.2f} | {r.roofline_fraction:.3f} |")
    return "\n".join(lines)
