from repro.roofline.hlo import collective_bytes, count_ops
from repro.roofline.report import (RooflineTerms, load_artifacts,
                                   markdown_table, model_flops_for, to_terms)

__all__ = ["RooflineTerms", "collective_bytes", "count_ops",
           "load_artifacts", "markdown_table", "model_flops_for", "to_terms"]
