"""Collective-traffic extraction from compiled HLO text.

cost_analysis() has no collective-bytes entry, so we parse the optimized
HLO: every all-gather / all-reduce / reduce-scatter / all-to-all /
collective-permute instruction contributes its *result* byte size (the
per-device wire traffic of a ring implementation is (n-1)/n of that —
close enough at n=16..512, and consistent across cells).
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

# e.g.:  %all-reduce.7 = f32[2048,128]{1,0} all-reduce(...)
_RE = re.compile(
    r"=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\][^ ]*\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
# tuple-result collectives:  = (f32[..], f32[..]) all-reduce(
_RE_TUPLE = re.compile(
    r"=\s*\(([^)]*)\)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
)
_RE_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    for d in dims.split(","):
        if d:
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device bytes by collective kind + 'total'."""
    out: Dict[str, float] = defaultdict(float)
    for line in hlo_text.splitlines():
        m = _RE.search(line)
        if m and not line.lstrip().startswith("ROOT (") :
            dtype, dims, kind = m.groups()
            out[kind] += _shape_bytes(dtype, dims)
            continue
        m = _RE_TUPLE.search(line)
        if m:
            shapes, kind = m.groups()
            for dt, dd in _RE_SHAPE.findall(shapes):
                out[kind] += _shape_bytes(dt, dd)
    out["total"] = sum(v for k, v in out.items() if k != "total")
    return dict(out)


def count_ops(hlo_text: str, opname: str) -> int:
    return len(re.findall(rf"\b{re.escape(opname)}\(", hlo_text))


# --- per-dot FLOP attribution (hillclimb evidence) -------------------------

_RE_DEF = re.compile(r"%([\w.\-]+)\s*=\s*(?:\()?\s*([a-z0-9]+)\[([0-9,]*)\]")
_RE_DOT = re.compile(r"=\s*([a-z0-9]+)\[([0-9,]*)\][^=]*?\bdot\(%([\w.\-]+)")
_RE_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")


def dot_flops(hlo_text: str, top: int = 0):
    """Sum 2*prod(out)*contract_size over every dot in the HLO. Operand
    shapes are resolved through a name->shape map built from instruction
    definitions (optimized HLO references operands by name). Exact for
    unrolled programs; per-trip-count for scanned ones.
    Returns (total, top-N [(flops, line)])."""
    shapes = {}
    for line in hlo_text.splitlines():
        md = _RE_DEF.search(line)
        if md:
            shapes[md.group(1)] = [int(d) for d in md.group(3).split(",") if d]
    total = 0.0
    items = []
    for line in hlo_text.splitlines():
        m = _RE_DOT.search(line)
        if not m:
            continue
        out_dims = [int(d) for d in m.group(2).split(",") if d]
        lhs_dims = shapes.get(m.group(3), [])
        mc = _RE_CONTRACT.search(line)
        cdims = [int(d) for d in mc.group(1).split(",")] if mc and mc.group(1) else []
        csize = 1
        for c in cdims:
            if c < len(lhs_dims):
                csize *= lhs_dims[c]
        f = 2.0 * csize
        for d in out_dims:
            f *= d
        total += f
        items.append((f, line.strip()[:160]))
    items.sort(key=lambda t: -t[0])
    return total, items[:top] if top else items
