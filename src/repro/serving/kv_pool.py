"""Paged KV-cache block allocator (host-side control plane).

The device holds one KV pool per attention layer, laid out
``[num_pages, page_size, K, h]`` (see ``stacks.cache_template(paged=True)``).
This module owns the *metadata*: which physical pages belong to which slot,
page refcounts, the free list, and the prefix cache. All decisions are made
on the host between engine ticks; the device only ever sees the resulting
``[n_slots, pages_per_slot]`` int32 page table (and an occasional page-copy
for copy-on-write), so the data plane stays fixed-shape and jit-friendly.

Design points (vLLM's block allocator, re-expressed for fixed-shape XLA):

- **Null page.** Physical page 0 is reserved: padding entries of every table
  row point at it, retired slots' rows are reset to it (so a done slot still
  riding through a fused tick writes into a sink, never into a page that has
  been handed to another slot), and its contents are never read unmasked.
- **Refcounting + prefix cache.** Full pages holding a prompt prefix are
  content-addressed by a prefix-closed digest (the hash covers *all*
  positions up to the page's end, so a hit implies the entire prefix
  matches). Repeated robot observations — the same camera frame +
  instruction resubmitted every control step — share those pages instead of
  holding duplicate KV, and ``prefix_hits`` counts the pages saved.
- **Copy-on-write.** Writing into a page with refcount > 1 first copies it
  to a fresh page (``prepare_write`` returns the (src, dst) pairs; the
  engine materializes them with one jitted gather/scatter). The engine's
  admit path only ever shares *full* prompt pages, which decode never
  rewrites, so COW fires only for explicit ``fork`` users (beam /
  speculative decoding) — but the invariant is enforced here, not assumed.
- **Cached-page retention.** When a hashed page's refcount drops to zero it
  is *retained* (LRU) rather than freed, so the next identical observation
  still hits even after the first request finished. Retained pages are
  reclaimed on demand, oldest first, when the free list runs dry — cache
  capacity costs nothing until there is real allocation pressure.

The pool never touches device memory: quantized pools' per-page scale
arrays (see ``models.kv_quant``) are cache-pytree leaves indexed by the
same physical page ids this class hands out, so every engine-side page
operation (scatter, COW copy, reuse-after-free) moves scales in lockstep
with values without the allocator knowing quantization exists. Format and
dataflow docs: docs/kv-cache.md, docs/architecture.md.
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Dict, List, Sequence, Tuple

import numpy as np


class PoolExhausted(RuntimeError):
    """No free pages left; admission should defer (re-queue) the request."""


class KVPool:
    """Block allocator for one serving engine's paged KV caches.

    Parameters
    ----------
    num_pages: total physical pages, *including* the reserved null page 0.
    page_size: tokens per page.
    n_slots / pages_per_slot: shape of the page table handed to the device.

    Invariants (every public method preserves all of them):

    - ``page_table`` is ``[n_slots, pages_per_slot]`` int32; row ``b``
      holds ``slot_pages[b]`` left-justified, padded with the null page 0.
      Logical position ``i`` of slot ``b`` lives at
      ``(page_table[b, i // page_size], i % page_size)``.
    - Page 0 is never allocated, never freed, never hashed; ``refcount[0]``
      is pinned at 1. Every table entry that does not name a live page
      names page 0 (the device-side write sink).
    - ``refcount[p] > 0`` iff some slot's page list (or a mid-call
      transaction) references ``p``; refcount 0 means ``p`` is on the free
      list, or — if it still carries a prefix hash — in the retained LRU.
    - Prefix digests are *prefix-closed* (key ``i`` covers all positions up
      to page ``i``'s end), so ``admit`` may share exactly a leading run of
      hit pages; ``_hash_to_page`` only ever points at pages whose KV has
      actually been written (rollback drops registrations of fresh pages).
    - Mutating methods are atomic under ``PoolExhausted``: ``admit`` and
      ``prepare_write`` roll back partial work before raising, so the
      caller observes either the full transition or none of it.
    """

    def __init__(self, num_pages: int, page_size: int, n_slots: int,
                 pages_per_slot: int):
        if num_pages < 2:
            raise ValueError("need at least one allocatable page + null page")
        self.num_pages = num_pages
        self.page_size = page_size
        self.n_slots = n_slots
        self.pages_per_slot = pages_per_slot
        self.refcount = np.zeros(num_pages, np.int32)
        self.refcount[0] = 1                       # null page, never freed
        self._free: List[int] = list(range(num_pages - 1, 0, -1))
        self.page_table = np.zeros((n_slots, pages_per_slot), np.int32)
        self.slot_pages: List[List[int]] = [[] for _ in range(n_slots)]
        self._hash_to_page: Dict[bytes, int] = {}
        self._page_hash: Dict[int, bytes] = {}
        self._cached: "OrderedDict[int, None]" = OrderedDict()  # LRU, ref==0
        self.reserve = 0                           # decode-headroom pages
        # stats
        self.prefix_hits = 0                       # pages reused via prefix cache
        self.pages_hwm = 0                         # high-water pages in use

    # -- accounting --------------------------------------------------------
    @property
    def pages_in_use(self) -> int:
        """Pages referenced by live slots (excludes retained cache pages)."""
        return (self.num_pages - 1) - len(self._free) - len(self._cached)

    @property
    def cached_pages(self) -> int:
        """Zero-ref prefix pages retained for future hits (reclaimable)."""
        return len(self._cached)

    def num_pages_for(self, length: int) -> int:
        """Pages needed to cover ``length`` positions (ceil division)."""
        return -(-length // self.page_size)

    def byte_stats(self, bytes_per_page: int) -> dict:
        """Page counts priced at a caller-supplied per-page byte cost. The
        pool tracks page *indices* only and stays layout-blind: the engine
        passes its global bytes-per-page for the summed figure and its
        per-device bytes-per-page when the cache leaves are sharded across
        an accelerator mesh — same pool, no layout knowledge here."""
        return {"bytes_in_use": self.pages_in_use * bytes_per_page,
                "bytes_hwm": self.pages_hwm * bytes_per_page}

    def slot_len_capacity(self, slot: int) -> int:
        """Positions the slot's currently-held pages can store; decode past
        this must ``ensure`` growth first or its write lands out of range."""
        return len(self.slot_pages[slot]) * self.page_size

    # -- allocation core ---------------------------------------------------
    def set_reserve(self, n_pages: int):
        """Reserve ``n_pages`` of decode headroom: admission-side allocation
        (``admit`` / ``ensure(use_reserve=False)``) refuses to dip into the
        last ``n_pages`` of supply, so in-flight decodes can always grow
        into their next page instead of deadlocking behind a fresh prompt
        that grabbed the final free page. Decode-side growth and COW pass
        ``use_reserve=True`` and may consume the reserve."""
        if n_pages < 0 or n_pages > self.num_pages - 1:
            raise ValueError(f"reserve {n_pages} out of range "
                             f"(pool has {self.num_pages - 1} pages)")
        self.reserve = n_pages

    def _supply(self, use_reserve: bool) -> int:
        """Pages allocatable right now (free list + reclaimable cached),
        minus the decode-headroom reserve for admission-side callers."""
        supply = len(self._free) + len(self._cached)
        return supply if use_reserve else supply - self.reserve

    def _alloc(self, use_reserve: bool = True) -> int:
        if self._supply(use_reserve) <= 0:
            raise PoolExhausted(
                f"KV pool exhausted: {self.num_pages - 1} pages, "
                f"{self._supply(True)} allocatable, "
                f"reserve {self.reserve} "
                f"({'decode' if use_reserve else 'admission'} side)")
        if self._free:
            pid = self._free.pop()
        else:
            pid, _ = self._cached.popitem(last=False)   # evict oldest
            self._drop_hash(pid)
        self.refcount[pid] = 1
        self.pages_hwm = max(self.pages_hwm, self.pages_in_use)
        return pid

    def _drop_hash(self, pid: int):
        key = self._page_hash.pop(pid, None)
        if key is not None and self._hash_to_page.get(key) == pid:
            del self._hash_to_page[key]

    def _incref(self, pid: int):
        if self.refcount[pid] == 0:                     # revive cached page
            self._cached.pop(pid, None)
        self.refcount[pid] += 1

    def _decref(self, pid: int):
        assert self.refcount[pid] > 0, pid
        self.refcount[pid] -= 1
        if self.refcount[pid] == 0:
            if pid in self._page_hash:
                self._cached[pid] = None                # retain for reuse
            else:
                self._free.append(pid)

    def _sync_table_row(self, slot: int):
        row = self.page_table[slot]
        row[:] = 0
        pages = self.slot_pages[slot]
        row[:len(pages)] = pages

    # -- slot lifecycle ----------------------------------------------------
    def can_admit(self, seq_len: int,
                  prefix_keys: Sequence[bytes] = ()) -> bool:
        """Whether ``admit(slot, seq_len, prefix_keys)`` would succeed right
        now, without touching any state. Lets the engine check capacity
        *before* paying for vision + prefill on a request it would only have
        to defer. Accounts for prefix pages that sit in the retained cache:
        a hit revives such a page, so it is shared *and* no longer
        reclaimable — counting it as both would overstate supply."""
        n_pages = self.num_pages_for(seq_len)
        if n_pages > self.pages_per_slot:
            return True     # let admit() raise the ValueError
        n_full = seq_len // self.page_size
        n_shared = shared_cached = 0
        for i in range(min(n_full, len(prefix_keys))):
            pid = self._hash_to_page.get(prefix_keys[i])
            if pid is None:
                break
            n_shared += 1
            if self.refcount[pid] == 0:
                shared_cached += 1   # a hit revives it: not reclaimable too
        supply = self._supply(use_reserve=False) - shared_cached
        return n_pages - n_shared <= supply

    def match_prefix(self, prefix_keys: Sequence[bytes]) -> int:
        """Leading run of prefix digests already registered in the prefix
        cache — the pages a matching request can *share* (and, in the
        chunked-prefill engine, skip recomputing: prefill starts at the
        first non-shared token). Read-only; prefix-closed digests make the
        leading-run check sufficient."""
        n = 0
        for key in prefix_keys:
            if key not in self._hash_to_page:
                break
            n += 1
        return n

    def admit(self, slot: int, seq_len: int,
              prefix_keys: Sequence[bytes] = (),
              register: bool = True) -> Tuple[List[int], int]:
        """Allocate pages covering ``seq_len`` positions for ``slot``.

        ``prefix_keys`` are prefix-closed digests for each *full* page of
        the prompt (key i covers positions [0, (i+1)*page_size)). A leading
        run of keys already in the prefix cache is shared (refcount bump, no
        new pages); everything else is freshly allocated and — with
        ``register`` (the monolithic-prefill default, where the caller
        scatters all prompt KV before anything else runs) — the fresh full
        pages are registered so later requests can hit them. The chunked
        engine passes ``register=False`` and registers pages via
        ``register_prefix_pages`` only after their chunk is actually
        written, so a digest can never resolve to a page whose KV does not
        exist yet.

        Admission-side: never dips into the decode-headroom reserve.
        Atomic: on PoolExhausted, nothing is retained. Returns
        (page ids, n_shared).
        """
        assert not self.slot_pages[slot], f"slot {slot} still holds pages"
        n_pages = self.num_pages_for(seq_len)
        if n_pages > self.pages_per_slot:
            raise ValueError(f"seq_len {seq_len} exceeds slot capacity "
                             f"{self.pages_per_slot * self.page_size}")
        n_full = seq_len // self.page_size
        pages: List[int] = []
        n_shared = 0
        for i in range(min(n_full, len(prefix_keys))):
            pid = self._hash_to_page.get(prefix_keys[i])
            if pid is None:
                break
            self._incref(pid)
            pages.append(pid)
            n_shared += 1
        try:
            for i in range(n_shared, n_pages):
                pid = self._alloc(use_reserve=False)
                pages.append(pid)
                if register and i < n_full and i < len(prefix_keys):
                    self._hash_to_page[prefix_keys[i]] = pid
                    self._page_hash[pid] = prefix_keys[i]
        except PoolExhausted:
            for pid in pages[:n_shared]:
                self._decref(pid)
            for pid in pages[n_shared:]:
                # fresh pages hold no KV yet — drop their hash registration
                # so the rollback cannot leave prefix-cache entries pointing
                # at never-written pages, and free them outright
                self._drop_hash(pid)
                self.refcount[pid] = 0
                self._free.append(pid)
            raise
        self.prefix_hits += n_shared
        self.slot_pages[slot] = pages
        self._sync_table_row(slot)
        return pages, n_shared

    def ensure(self, slot: int, length: int,
               use_reserve: bool = True) -> List[int]:
        """Grow ``slot`` to cover ``length`` positions (capped at slot
        capacity). Returns the freshly allocated page ids. Raises
        ``PoolExhausted`` with the slot partially grown — already-appended
        pages stay owned by the slot (they are valid growth, not a broken
        transaction), so a retry after the caller frees pressure continues
        where this call stopped. ``use_reserve=False`` marks admission-side
        growth (chunked prefill) that must not eat the decode headroom;
        the default is decode-side growth, which may."""
        length = min(length, self.pages_per_slot * self.page_size)
        fresh: List[int] = []
        while self.slot_len_capacity(slot) < length:
            pid = self._alloc(use_reserve=use_reserve)
            self.slot_pages[slot].append(pid)
            fresh.append(pid)
        if fresh:
            self._sync_table_row(slot)
        return fresh

    def register_prefix_pages(self, slot: int,
                              prefix_keys: Sequence[bytes],
                              n_written: int) -> int:
        """Register the slot's full prompt pages whose KV has now been
        written (chunked prefill calls this after each chunk lands,
        ``n_written`` = prompt positions written so far). Only pages that
        carry no hash yet are registered — shared (hit) pages already have
        one — and a digest is never re-pointed away from a live page, so
        the prefix-closed invariant (``_hash_to_page`` only names
        written-KV pages) holds at every tick boundary. Returns how many
        pages were newly registered."""
        pages = self.slot_pages[slot]
        n = 0
        for i in range(min(n_written // self.page_size, len(prefix_keys),
                           len(pages))):
            pid = pages[i]
            if pid in self._page_hash:
                continue
            key = prefix_keys[i]
            if key in self._hash_to_page:
                continue        # another slot registered this digest first
            self._hash_to_page[key] = pid
            self._page_hash[pid] = key
            n += 1
        return n

    def prepare_write(self, slot: int, start: int,
                      end: int) -> List[Tuple[int, int]]:
        """Make positions [start, end) of ``slot`` safely writable:
        copy-on-write any shared page in the range. Returns (src, dst) page
        pairs the caller must copy on device before writing. Atomic: if the
        pool runs out mid-COW, completed swaps are rolled back (the caller
        never learns of pairs it would then fail to copy) and the exception
        propagates with the slot in its pre-call state."""
        copies: List[Tuple[int, int]] = []
        pages = self.slot_pages[slot]
        idxs: List[int] = []
        try:
            for i in range(start // self.page_size,
                           min(self.num_pages_for(end), len(pages))):
                pid = pages[i]
                if self.refcount[pid] > 1:
                    new = self._alloc()
                    self._decref(pid)
                    pages[i] = new
                    copies.append((pid, new))
                    idxs.append(i)
        except PoolExhausted:
            for i, (old, new) in zip(reversed(idxs), reversed(copies)):
                self.refcount[new] = 0
                self._free.append(new)
                self._incref(old)        # was > 1 pre-COW, so never cached
                pages[i] = old
            self._sync_table_row(slot)
            raise
        if copies:
            self._sync_table_row(slot)
        return copies

    def fork(self, src: int, dst: int):
        """Share all of ``src``'s pages with ``dst`` (zero-copy refcount
        bumps; ``dst`` must be empty). Later writes on either side trigger
        copy-on-write via ``prepare_write`` — the beam/speculative-decoding
        entry point; the engine's own admit path never forks."""
        assert not self.slot_pages[dst], f"slot {dst} still holds pages"
        for pid in self.slot_pages[src]:
            self._incref(pid)
        self.slot_pages[dst] = list(self.slot_pages[src])
        self._sync_table_row(dst)

    def free_slot(self, slot: int):
        """Release the slot's pages (eviction on finish). Shared pages
        survive while other slots or the prefix cache's future hits need
        them; the table row resets to the null page so stale device-side
        writes land in the sink."""
        for pid in self.slot_pages[slot]:
            self._decref(pid)
        self.slot_pages[slot] = []
        self.page_table[slot, :] = 0
