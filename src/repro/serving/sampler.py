"""Token samplers for the serving path."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits, key=None):
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)


def sample(logits, key, temperature: float = 1.0, top_k: int = 0):
    """Temperature + optional top-k sampling. logits [B,1,V] -> [B]."""
    l = logits[:, -1].astype(jnp.float32)
    if temperature <= 0:
        return greedy(logits)
    l = l / temperature
    if top_k:
        kth = jnp.sort(l, axis=-1)[:, -top_k][:, None]
        l = jnp.where(l < kth, -1e30, l)
    return jax.random.categorical(key, l, axis=-1).astype(jnp.int32)


def sample_token(logits, key, temperature: float = 0.0, top_k: int = 0):
    """Sampler fused into the engine's device-resident decode tick:
    logits [B,1,V] -> tokens [B]. ``temperature``/``top_k`` are static at
    trace time; temperature <= 0 selects greedy (key unused)."""
    if temperature <= 0:
        return greedy(logits)
    return sample(logits, key, temperature, top_k)
