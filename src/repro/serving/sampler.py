"""Token samplers for the serving path."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits, key=None):
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)


def sample(logits, key, temperature: float = 1.0, top_k: int = 0):
    """Temperature + optional top-k sampling. logits [B,1,V] -> [B]."""
    l = logits[:, -1].astype(jnp.float32)
    if temperature <= 0:
        return greedy(logits)
    l = l / temperature
    if top_k:
        kth = jnp.sort(l, axis=-1)[:, -top_k][:, None]
        l = jnp.where(l < kth, -1e30, l)
    return jax.random.categorical(key, l, axis=-1).astype(jnp.int32)


def sample_token(logits, key, temperature: float = 0.0, top_k: int = 0):
    """Sampler fused into the engine's device-resident decode tick:
    logits [B,1,V] -> tokens [B]. ``temperature``/``top_k`` are static at
    trace time; temperature <= 0 selects greedy (key unused)."""
    if temperature <= 0:
        return greedy(logits)
    return sample(logits, key, temperature, top_k)


def spec_accept(draft, verify, *, eos: int, budget, room, live):
    """Greedy longest-prefix acceptance for self-speculative decode.

    ``draft`` [B, K] is the candidate chunk fed to the verifier: row 0 the
    token the reference would feed next (always "accepted" — it was already
    emitted or carried), rows 1..K-1 the draft model's proposals. ``verify``
    [B, K] is the full model's greedy argmax at each position; row j is the
    reference's next token after consuming ``draft[:, :j+1]``, so proposal
    ``draft[:, j]`` is *correct* iff it equals ``verify[:, j-1]``, and
    acceptance stops at the first mismatch (``a`` = accepted proposals).
    The emitted run is ``verify[:, :a+1]``: the ``a`` accepted tokens
    re-emitted from the verifier — bit-identical to the per-token reference
    stream — plus one **bonus** token, the verifier's correction at the
    first mismatch (or its extension when every proposal was accepted).
    Either way a verify pass always advances >= 1 token, so speculation
    never does worse than the plain fused tick in tokens per pass.

    ``budget`` (remaining per-request token budget) and ``room`` (remaining
    per-tick quota) [B] cap the emit count; a first EOS *inside* the
    emitted run truncates it (tokens after an emitted EOS must never reach
    the stream, exactly as the per-token reference stops). ``live`` [B]
    marks slots participating this round; dead slots emit 0.

    Returns ``(n_emit [B] int32, done [B] bool)``: live slots emit
    ``1..K`` tokens (``verify[:, :n_emit]``); ``done`` marks slots whose
    final emitted token is EOS or whose budget hit zero."""
    B, K = draft.shape
    budget = jnp.asarray(budget, jnp.int32)
    room = jnp.asarray(room, jnp.int32)
    if K > 1:
        ok = jnp.cumprod((draft[:, 1:] == verify[:, :-1]).astype(jnp.int32),
                         axis=1)
        a = jnp.sum(ok, axis=1).astype(jnp.int32)       # accepted proposals
    else:
        a = jnp.zeros((B,), jnp.int32)
    n_emit = jnp.minimum(a + 1, jnp.minimum(budget, room))
    iseos = verify == eos
    first_eos = jnp.argmax(iseos, axis=1).astype(jnp.int32)
    eos_cut = jnp.where(iseos.any(axis=1), first_eos + 1, K + 1)
    n_emit = jnp.minimum(n_emit, eos_cut)
    n_emit = jnp.where(live, jnp.maximum(n_emit, 1), 0).astype(jnp.int32)
    last = jnp.take_along_axis(verify, jnp.maximum(n_emit - 1, 0)[:, None],
                               axis=1)[:, 0]
    done = live & ((last == eos) | (budget - n_emit <= 0))
    return n_emit, done
