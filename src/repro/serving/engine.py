"""Continuous-batching serving engine with a device-resident decode loop.

Decode runs over a fixed slot batch [B_slots]; each slot carries its own
cache position (per-slot `index` vector — see layers.update_cache /
attention_decode). Finished slots are refilled from the request queue via a
jitted prefill whose cache slice is scattered into the slot cache. This is
vLLM-style continuous batching re-expressed in fixed shapes (the
XLA-friendly formulation): no recompilation on admit/evict.

Two decode paths:

- **fused** (default): one jitted multi-token tick — a ``lax.while_loop``
  over up to ``tick_tokens`` decode steps that carries per-slot
  index/budget/done state as device arrays and fuses sampling into the step.
  The host is consulted only when a slot finishes or the tick's token budget
  is exhausted, so an N-token decode costs ~ceil(N/K) host syncs instead of
  N. This attacks exactly the launch/sync overhead the paper identifies as
  first-order for the memory-bound action-generation phase.
- **reference**: the original one-token-per-tick path (``step()``), kept for
  equivalence testing and as the bit-exactness oracle under greedy sampling.
- **speculative** (``spec_decode=True``; fused-only, greedy-only): each
  round of the fused tick drafts ``spec_k - 1`` tokens with a
  layer-truncated (optionally int8/fp8 weight-quantized) draft of the
  *same* model, verifies all ``spec_k`` positions through the full model in
  one banded chunk-prefill dispatch against the live cache, and emits the
  greedy longest-prefix-accepted run plus one bonus token — bit-equal to
  the reference stream at up to ``spec_k`` accepted tokens per full
  weight+cache HBM pass, which is exactly the memory-bound
  action-generation pass the paper measures as the bottleneck. Rejected
  speculative KV needs no undo: the next round's full-model chunk rewrites
  those positions before any read (causal masking never looks past a
  slot's live position), and rows past the cache capacity sink into the
  paged null page / dense scatter drop. See docs/speculative.md.

Two cache layouts (``paged=``):

- **dense** (default, and the equivalence oracle): per-slot
  ``[n_slots, max_seq, K, h]`` buffers, over-allocated at ``max_seq``;
  admission scatters the batch-1 prefill cache into the slot's batch row.
- **paged**: attention K/V lives in shared ``[num_pages, page_size, K, h]``
  pools addressed through a per-slot page table (``serving.kv_pool``).
  Admission allocates pages (sharing full prompt-prefix pages with the
  pool's prefix cache — repeated robot observations are not re-stored) and
  scatters prefill KV page-wise; finish frees pages back to the pool. Cache
  memory scales with pages actually used, not ``max_seq`` per slot, and
  ``EngineStats`` tracks pages-in-use / cache-bytes high-water / prefix
  hits. ``kv_dtype="int8"``/``"fp8"`` stores the pools as 1-byte codes
  with per-page-per-head f32 scale siblings (quantize on scatter and on
  decode write, dequantize in the decode read — see models.kv_quant and
  docs/kv-cache.md), shrinking cache bytes and decode HBM traffic to
  ~0.52x the bf16-equivalent at int8.

Two admission policies:

- **admit-stall** (default): a popped request runs its whole prompt through
  one monolithic prefill dispatch before anything else proceeds.
- **chunked** (``chunked_prefill=True``): a Sarathi-style token-budget
  scheduler (``serving.scheduler``) splits prompts into fixed-size chunks
  and packs them with decode into each tick, so a long prompt never stalls
  an active decoder beyond the budget. Prefill-from-position makes a
  prefix-cache hit *skip* the shared compute (chunking starts at the first
  non-shared token), and pool admission becomes chunk-granular with a
  decode-headroom reserve + longest-idle eviction. See docs/scheduler.md.

Phase latency accounting (vision / prefill / decode) is recorded per request
and aggregated in ``EngineStats`` — the serving-side counterpart of the
paper's Nsight phase decomposition — and survives the fusion: vision runs as
its own jitted stage (``M.encode_vision`` feeding ``batch['prefix']``), and
decode wall-time is attributed per tick. Per-request ``queue_s``/``ttft_s``
and per-tick latency lists (``tick_s``/``decode_tick_s``, with p50/p99 in
``phase_report()``) make scheduler jitter observable.
"""
from __future__ import annotations

import dataclasses
import functools
import hashlib
import math
import time
import warnings
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.distributed.sharding import serving_rules, spec_for
from repro.models import kv_quant
from repro.models import model as M
from repro.models.layers import ModelOptions, band_len
from repro.models.params import is_pspec
from repro.models.stacks import (cache_batch_axis, cache_template,
                                 is_paged_leaf, is_scale_leaf, stack_plan)
from repro.serving import sampler as S
from repro.serving.kv_pool import KVPool, PoolExhausted
from repro.serving.scheduler import (BEST_EFFORT, ChunkedScheduler, ChunkPlan,
                                     PrefillTask, SLOController,
                                     eviction_victims, insert_by_class,
                                     is_realtime, req_deadline)


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [S] int32
    max_tokens: int
    patches: Optional[np.ndarray] = None
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    cancelled: bool = False            # aborted via ServingEngine.cancel()
    t_submit: float = 0.0
    t_prefill: float = 0.0
    t_done: float = 0.0
    queue_s: float = 0.0               # submit -> prefill start (queue wait)
    ttft_s: float = 0.0                # submit -> first token
    pages_used: int = 0                # paged engine: pages held at finish
    pages_shared: int = 0              # paged engine: prefix-cache hits
    prefill_skipped: int = 0           # prompt positions skipped (prefix hit)
    priority: str = BEST_EFFORT        # scheduling class ("realtime" jumps
    #                                    the queue, EDF within class)
    deadline_s: float = 0.0            # relative SLO (0 = none); the
    #                                    absolute deadline is stamped at
    #                                    submit time
    t_deadline: float = math.inf       # t_submit + deadline_s (set by
    #                                    ServingEngine.submit; inf = none)


@dataclass
class EngineStats:
    """Host-sync contract + phase + cache accounting for one engine lifetime.

    A "sync" is a device->host readback that blocks the Python loop (the
    per-token ``np.asarray``/``int()`` the paper's launch-overhead term maps
    to). The fused path pays one per tick; the reference path one per token.

    The cache fields are live only on the paged engine: ``pages_in_use`` /
    ``pages_hwm`` count pool pages referenced by live slots,
    ``cache_bytes_hwm`` is the high-water of their device bytes (summed over
    every attention layer's K+V pools *at the pool's storage dtype* — a
    quantized engine's figure reflects the 1-byte codes plus their f32
    scale rows, not the bf16/f32 equivalent), and ``prefix_hits`` counts
    pages served from the prefix cache instead of being re-stored.
    """
    decode_syncs: int = 0       # blocking readbacks on the decode path
    prefill_syncs: int = 0      # blocking readbacks at admission
    ticks: int = 0              # engine ticks (fused or reference)
    device_steps: int = 0       # decode steps executed on device
    tokens_decoded: int = 0     # tokens emitted by the decode path
    vision_time: float = 0.0
    prefill_time: float = 0.0
    decode_time: float = 0.0
    prefill_tokens: int = 0     # prompt positions actually run through prefill
    prefill_skipped: int = 0    # prompt positions skipped via prefix-cache hit
    # key-lane accounting for the banded prefill-with-cache core: per prefill
    # dispatch, every query row attends a key axis of the banded live-prefix
    # length instead of the full max_seq view the pre-dispatcher core used.
    # prefill_key_lanes sums rows x attended lanes; *_full sums the same
    # rows x max_seq — their ratio is the structurally recovered key-axis
    # factor (phase_report()["prefill_key_lane_ratio"]).
    prefill_key_lanes: int = 0       # sum of rows x banded key length
    prefill_key_lanes_full: int = 0  # rows x max_seq (old full-view core)
    pages_in_use: int = 0       # paged: current pool pages held by live slots
    pages_hwm: int = 0          # paged: high-water pages in use
    cache_bytes_hwm: int = 0    # paged: high-water KV bytes actually held
    prefix_hits: int = 0        # paged: pages reused via the prefix cache
    # sharded serving (ServingEngine mesh=...): mesh_shape names the mesh
    # axes, e.g. (("model", 4),), and cache_bytes_hwm_shard is the
    # *per-device* byte high-water — each shard stores its own heads' slice
    # of every page, so the honest per-device figure is ~1/N of the summed
    # cache_bytes_hwm (replicated leaves, e.g. a head-replication fallback,
    # keep it higher). Without a mesh, shard == total.
    mesh_shape: Optional[Tuple] = None
    cache_bytes_hwm_shard: int = 0
    # queue_s / ttft_s are per-*event* samples: one entry per admission
    # (submit -> prefill start) and per prefill completion (submit -> first
    # token). Without preemption that is exactly one entry per request; a
    # preempted-and-retried request contributes an entry per attempt that
    # reached the boundary (the Request's own fields hold the final values).
    # prefill_tokens likewise counts prompt positions actually *executed* —
    # a preempted prefill's re-run is real work and is counted again.
    queue_s: List[float] = field(default_factory=list)
    ttft_s: List[float] = field(default_factory=list)
    tick_s: List[float] = field(default_factory=list)    # whole-tick wall
    decode_tick_s: List[float] = field(default_factory=list)  # decode stage
    tick_prefill_tokens: List[int] = field(default_factory=list)  # per tick:
    # prompt positions prefilled inside that tick — the head-of-line metric
    # (admit-stall pays a whole prompt in one tick; the scheduler's entry
    # never exceeds its token budget)
    tick_key_lanes: List[int] = field(default_factory=list)  # per tick: key
    # lanes (rows x banded length) the tick's prefill dispatches attended
    # speculative decode (spec_decode=True). A verify "pass" is one
    # full-model chunk dispatch over spec_k positions for one live slot —
    # the weight+cache HBM pass speculation amortizes. accept_hist[n]
    # counts passes that emitted n tokens (accepted prefix + bonus), so
    # emitted / passes — phase_report()["spec_accept_per_pass"] — is the
    # tokens-per-HBM-pass factor the spec_decode bench gates >= 2x.
    # Draft cost is tracked both as raw truncated steps and as full-model
    # pass equivalents (steps x draft_layers / num_layers), giving the
    # draft/verify phase split. spec_key_lanes uses the *per-slot* banded
    # bound (satellite: per-slot live bounds) vs the max_seq view.
    spec_verify_passes: int = 0
    spec_draft_steps: int = 0            # truncated draft steps executed
    spec_draft_pass_equiv: float = 0.0   # draft cost in full-model passes
    spec_accept_hist: List[int] = field(default_factory=list)
    spec_key_lanes: int = 0              # verify rows x per-slot band bound
    spec_key_lanes_full: int = 0         # verify rows x max_seq
    # deadline + preemption accounting (SLO-aware scheduling). Only
    # requests carrying a deadline (deadline_s > 0) count toward
    # attainment — an undeadlined request can neither hit nor miss.
    # Preemptions are keyed by the *victim's* class; the policy invariant
    # (realtime is never an admission-side victim) makes
    # preemptions["realtime"] > 0 on that path a bug, not a statistic.
    deadline_hit: Dict[str, int] = field(default_factory=dict)
    deadline_miss: Dict[str, int] = field(default_factory=dict)
    preemptions: Dict[str, int] = field(default_factory=dict)
    tick_ewma_s: float = 0.0    # EWMA whole-tick wall (alpha 0.2) — the
    #                             live tick-cost estimate the SLO
    #                             controller and Backpressure quote from

    def record_tick_wall(self, wall_s: float):
        """Fold one tick's wall time into the EWMA (first sample seeds)."""
        self.tick_ewma_s = (wall_s if self.tick_ewma_s == 0.0
                            else 0.8 * self.tick_ewma_s + 0.2 * wall_s)

    def record_deadline(self, req) -> None:
        """Score a finishing request against its absolute deadline."""
        if not (req.deadline_s > 0):
            return
        cls = req.priority
        bucket = (self.deadline_hit if req.t_done <= req.t_deadline
                  else self.deadline_miss)
        bucket[cls] = bucket.get(cls, 0) + 1

    def record_preemption(self, req) -> None:
        self.preemptions[req.priority] = \
            self.preemptions.get(req.priority, 0) + 1

    def phase_report(self) -> Dict[str, float]:
        """Figure-2-style wall-time decomposition, plus decode-tick latency
        percentiles (p50/p99 over the per-tick decode stage) so scheduler
        jitter — a prefill chunk crowding the tick a decoder needed — is
        observable, not just the aggregate mean. When prefill ran,
        ``prefill_key_lane_ratio`` is the banded core's key-axis work over
        the old full-``max_seq``-view equivalent — the paper-style phase
        accounting for the recovered ~max_seq/S prefill factor. Per-request
        queue-wait and TTFT percentiles (``queue_p50/p99``, ``ttft_p50/p99``,
        seconds, present once any request reached the respective boundary)
        make a stalled fleet diagnosable from a front-end log line: a
        growing queue_p99 with flat decode percentiles means admission is
        starved (pool pressure / backlog), not that decode got slower."""
        rep = {"vision": self.vision_time, "prefill": self.prefill_time,
               "decode": self.decode_time}
        if self.decode_tick_s:
            rep["decode_tick_p50"] = float(np.percentile(self.decode_tick_s,
                                                         50))
            rep["decode_tick_p99"] = float(np.percentile(self.decode_tick_s,
                                                         99))
        for name, samples in (("queue", self.queue_s), ("ttft", self.ttft_s)):
            if samples:
                rep[f"{name}_p50"] = float(np.percentile(samples, 50))
                rep[f"{name}_p99"] = float(np.percentile(samples, 99))
        if self.prefill_key_lanes_full:
            rep["prefill_key_lane_ratio"] = (self.prefill_key_lanes
                                             / self.prefill_key_lanes_full)
        # per-class deadline attainment (requests with deadline_s > 0
        # only) and preemption counts, keyed by class suffix — the SLO
        # scheduler's scoreboard and the `slo` bench gate's input
        for cls in sorted(set(self.deadline_hit) | set(self.deadline_miss)):
            hit = self.deadline_hit.get(cls, 0)
            miss = self.deadline_miss.get(cls, 0)
            rep[f"deadline_attainment_{cls}"] = hit / (hit + miss)
            rep[f"deadline_total_{cls}"] = float(hit + miss)
        for cls, n in sorted(self.preemptions.items()):
            rep[f"preemptions_{cls}"] = float(n)
        if self.tick_ewma_s:
            rep["tick_ewma_s"] = float(self.tick_ewma_s)
        # paged cache accounting (and, under a mesh, the per-device view:
        # scrapers must not read the summed figure as a per-device one)
        if self.pages_hwm:
            rep["pages_in_use"] = float(self.pages_in_use)
            rep["pages_hwm"] = float(self.pages_hwm)
            rep["cache_bytes_hwm"] = float(self.cache_bytes_hwm)
            rep["prefix_hits"] = float(self.prefix_hits)
        if self.mesh_shape:
            for ax, sz in self.mesh_shape:
                rep[f"mesh_{ax}"] = float(sz)
            if self.pages_hwm:
                rep["cache_bytes_hwm_shard"] = float(self.cache_bytes_hwm_shard)
                # every shard references the same page set (it owns a head
                # slice of each page), so the count is per-device as-is
                rep["pages_in_use_shard"] = float(self.pages_in_use)
        if self.spec_verify_passes:
            emitted = sum(n * c for n, c in enumerate(self.spec_accept_hist))
            rep["spec_verify_passes"] = float(self.spec_verify_passes)
            rep["spec_accept_per_pass"] = emitted / self.spec_verify_passes
            rep["spec_accept_hist"] = [int(c) for c in self.spec_accept_hist]
            rep["spec_draft_steps"] = float(self.spec_draft_steps)
            rep["spec_draft_pass_equiv"] = float(self.spec_draft_pass_equiv)
            # draft/verify phase split, in full-model-pass equivalents:
            # what fraction of the tick's model work went to drafting
            tot = self.spec_draft_pass_equiv + self.spec_verify_passes
            rep["spec_draft_frac"] = float(self.spec_draft_pass_equiv / tot)
            if self.spec_key_lanes_full:
                rep["spec_key_lane_ratio"] = (self.spec_key_lanes
                                              / self.spec_key_lanes_full)
        return rep


def prefix_page_keys(cfg_name: str, page_size: int, kv_dtype: str,
                     prompt: np.ndarray, patches: Optional[np.ndarray] = None,
                     n_prefix: int = 0) -> List[bytes]:
    """Prefix-closed digests, one per *full* page of a request's prompt
    prefix — the content address a ``KVPool`` shares pages under.

    Key ``i`` covers every input that determines KV for positions
    ``[0, (i+1)*page_size)``: the vision patches (one digest, repeated over
    the ``n_prefix`` positions they fill) and the prompt tokens so far. The
    seed also covers the model name, page size, and pool storage dtype, so
    two pools can only ever share pages when their page contents would be
    bit-identical for identical prompts.

    Module-level (not an engine method) because the digest is also the
    *routing key*: ``serving.frontend`` computes it per candidate replica
    before any engine owns the request, and routes repeat observations to
    the replica whose pool already holds the prefix pages.
    """
    h = hashlib.sha1(f"{cfg_name}:{page_size}:{kv_dtype}".encode())
    items: List[bytes] = []
    if n_prefix:
        pd = hashlib.sha1(np.ascontiguousarray(patches).tobytes()).digest()
        items.extend([pd] * n_prefix)
    items.extend(int(t).to_bytes(8, "little", signed=True) for t in prompt)
    keys = []
    for i, item in enumerate(items):
        h.update(item)
        if (i + 1) % page_size == 0:
            keys.append(h.digest())
    return keys


def _fused_tick(cfg: ModelConfig, opts: ModelOptions, K: int, eos: int,
                temperature: float, top_k: int, stop_on_finish: bool,
                params, tokens, caches, index, budget, done, key,
                max_steps, page_table=None):
    """Up to K decode steps on device. Per-slot carry: current token [B,1],
    cache position index [B], remaining budget [B], done [B]. Emitted tokens
    land in out [B,K] (each live slot fills a prefix of its row, length
    n_emit[s]). Exits early when every slot is done or — with
    ``stop_on_finish`` — as soon as any slot newly finishes, so the host can
    refill it. ``max_steps`` (dynamic scalar <= K) lets the chunked
    scheduler cap the tick's decode depth to its token budget without
    recompiling; K stays the compiled loop bound. ``page_table`` [B,npg]
    selects the paged cache layout (pages for index..index+K-1 are
    pre-allocated by the host)."""
    B = tokens.shape[0]
    out0 = jnp.full((B, K), -1, jnp.int32)
    n_emit0 = jnp.zeros((B,), jnp.int32)
    entry_done = done
    cap = jnp.minimum(jnp.asarray(K, jnp.int32),
                      jnp.asarray(max_steps, jnp.int32))

    def cond(c):
        step, _, _, _, _, done, _, _, _ = c
        go = (step < cap) & ~jnp.all(done)
        if stop_on_finish:
            go &= ~jnp.any(done & ~entry_done)
        return go

    def body(c):
        step, tokens, caches, index, budget, done, key, out, n_emit = c
        logits, caches = M.decode_step(cfg, opts, params, tokens, caches,
                                       index, page_table=page_table)
        key, sub = jax.random.split(key)
        nxt = S.sample_token(logits, sub, temperature, top_k)   # [B]
        live = ~done
        col = jnp.where(live, nxt, -1)[:, None]
        out = jax.lax.dynamic_update_slice(out, col, (0, step))
        n_emit = n_emit + live.astype(jnp.int32)
        budget = jnp.where(live, budget - 1, budget)
        newly = live & ((nxt == eos) | (budget <= 0))
        index = jnp.where(live, index + 1, index)
        tokens = jnp.where(live[:, None], nxt[:, None], tokens)
        return (step + 1, tokens, caches, index, budget, done | newly, key,
                out, n_emit)

    init = (jnp.asarray(0, jnp.int32), tokens, caches, index, budget, done,
            key, out0, n_emit0)
    (steps, tokens, caches, index, budget, done, key, out, n_emit) = \
        jax.lax.while_loop(cond, body, init)
    return tokens, caches, index, budget, done, key, out, n_emit, steps


def _fused_spec_tick(cfg: ModelConfig, opts: ModelOptions, T: int, K: int,
                     draft_blocks: int, eos: int, stop_on_finish: bool,
                     max_seq: int, live_len: int, params, draft_params,
                     tokens, caches, index, budget, done, max_steps,
                     page_table=None):
    """Self-speculative fused tick: each while-loop round is
    draft -> verify -> accept instead of one decode step.

    Round anatomy (per live slot at position ``index``, current token
    ``tokens`` whose KV is not yet written — decode writes-then-attends):

    1. **Draft**: ``K - 1`` layer-truncated greedy steps
       (``M.draft_step`` over the leading ``draft_blocks`` blocks of
       ``draft_params``) roll out candidates; together with the current
       token they form the chunk [B, K] at positions ``index..index+K-1``.
       The draft's leading-layer KV lands in the shared cache — stale
       after this round, rewritten below.
    2. **Verify**: one full-model banded chunk dispatch
       (``M.verify_chunk``) runs all K positions, writing *all* layers'
       KV at those positions (which erases the draft's partial writes and
       any previous round's rejected rows before anything reads them) and
       returning every position's logits.
    3. **Accept** (``S.spec_accept``): greedy longest-prefix acceptance +
       one bonus token; ``n_emit in 1..K`` tokens per live slot, capped by
       the slot's remaining budget, the tick quota ``cap - e``, and a
       first emitted EOS. The emitted tokens are the *verifier's* argmaxes,
       so streams are bit-equal to the per-token reference; the carry
       token is the last emitted one and ``index += n_emit``.

    Rejected rows (``index + n_emit .. index + K - 1``) hold stale KV but
    are never read: causal masking hides positions past a slot's query,
    and the next round's verify rewrites them first (position re-write
    rollback). Rows at or past ``max_seq`` are masked out of the write
    path entirely (``n_valid``) — dense scatter drop / paged null-page
    sink — so speculation never corrupts the last page. ``live_len`` is
    the static banded key bound covering the oldest slot through this
    tick's deepest verify row (the host collapses the per-slot bounds to
    their max — a per-slot tuple as a static jit argument would retrace
    per batch age mix).

    Extra carry vs ``_fused_tick``: ``hist`` [K+1] counts verify passes
    by tokens emitted (the accepted-per-pass histogram) and ``passes``
    [B] counts per-slot verify passes (its denominator). Greedy-only, so
    no RNG key rides the carry."""
    B = tokens.shape[0]
    out0 = jnp.full((B, T), -1, jnp.int32)
    e0 = jnp.zeros((B,), jnp.int32)
    hist0 = jnp.zeros((K + 1,), jnp.int32)
    passes0 = jnp.zeros((B,), jnp.int32)
    entry_done = done
    cap = jnp.minimum(jnp.asarray(T, jnp.int32),
                      jnp.asarray(max_steps, jnp.int32))
    kcol = jnp.arange(K, dtype=jnp.int32)

    def cond(c):
        _, _, _, _, done, _, e, _, _, _ = c
        go = jnp.any(~done & (e < cap))
        if stop_on_finish:
            go &= ~jnp.any(done & ~entry_done)
        return go

    def body(c):
        tokens, caches, index, budget, done, out, e, hist, passes, iters = c
        live = ~done & (e < cap)
        # -- draft: K-1 truncated steps, chunk[0] is the current token -----
        cur = tokens
        chunk = [cur]
        for j in range(K - 1):
            pos = index + j
            nv = (live & (pos < max_seq)).astype(jnp.int32)
            dlogits, caches = M.draft_step(cfg, opts, draft_params, cur,
                                           caches, pos, draft_blocks,
                                           page_table=page_table, n_valid=nv)
            cur = jnp.argmax(dlogits[:, -1], -1).astype(jnp.int32)[:, None]
            chunk.append(cur)
        chunk = jnp.concatenate(chunk, axis=1)                       # [B,K]
        # -- verify: all K positions through the full model in one chunk --
        nv = jnp.where(live, jnp.clip(max_seq - index, 0, K), 0)
        vlogits, caches = M.verify_chunk(cfg, opts, params, chunk, caches,
                                         index, n_valid=nv,
                                         page_table=page_table,
                                         live_len=live_len)
        verify = jnp.argmax(vlogits, -1).astype(jnp.int32)           # [B,K]
        # -- accept: longest prefix + bonus, budget/quota/EOS capped ------
        n_emit, newly = S.spec_accept(chunk, verify, eos=eos,
                                      budget=budget, room=cap - e,
                                      live=live)
        cols = jnp.where(live[:, None] & (kcol[None] < n_emit[:, None]),
                         e[:, None] + kcol[None], T)    # T = dropped
        out = out.at[jnp.arange(B)[:, None], cols].set(verify, mode="drop")
        nxt = jnp.take_along_axis(
            verify, jnp.maximum(n_emit - 1, 0)[:, None], axis=1)
        tokens = jnp.where(live[:, None], nxt, tokens)
        index = index + jnp.where(live, n_emit, 0)
        budget = budget - jnp.where(live, n_emit, 0)
        e = e + jnp.where(live, n_emit, 0)
        hist = hist.at[jnp.where(live, n_emit, K + 1)].add(1, mode="drop")
        passes = passes + live.astype(jnp.int32)
        return (tokens, caches, index, budget, done | newly, out, e, hist,
                passes, iters + 1)

    init = (tokens, caches, index, budget, done, out0, e0, hist0, passes0,
            jnp.asarray(0, jnp.int32))
    return jax.lax.while_loop(cond, body, init)


# Jitted stages are cached per static signature (configs are frozen
# dataclasses, hence hashable), so constructing many engines — tests, sweeps,
# one engine per model replica — shares compiled code instead of re-tracing.
@functools.lru_cache(maxsize=None)
def _jit_decode(cfg: ModelConfig, opts: ModelOptions):
    return jax.jit(lambda p, t, c, i, pt=None: M.decode_step(
        cfg, opts, p, t, c, i, page_table=pt))


@functools.lru_cache(maxsize=None)
def _jit_prefill(cfg: ModelConfig, opts: ModelOptions, max_seq: int):
    return jax.jit(lambda p, b: M.prefill(cfg, opts, p, b, max_seq,
                                          cache_dtype=jnp.float32))


@functools.lru_cache(maxsize=None)
def _jit_vision(cfg: ModelConfig, opts: ModelOptions):
    return jax.jit(lambda p, px: M.encode_vision(cfg, opts, p, px))


@functools.lru_cache(maxsize=None)
def _jit_prefill_chunk(cfg: ModelConfig, opts: ModelOptions, paged: bool):
    """Chunked-prefill stage: one fixed-shape dispatch per chunk. The chunk
    length is baked in by the embeds shape (jit retraces per shape, and the
    scheduler always pads to ``chunk_size``); ``cache_index``/``n_valid``
    are dynamic scalars so chunk *position* never recompiles. ``live``
    (static, last arg) is the banded attention core's key-axis bound — the
    engine rounds it up to whole bands, so it takes at most
    ``max_seq / prefill_band`` distinct values per chunk shape. Caches are
    donated — the engine rebinds the returned tree."""
    if paged:
        return jax.jit(
            lambda p, e, c, i, nv, pt, live: M.prefill_chunk(
                cfg, opts, p, e, c, i, n_valid=nv, page_table=pt,
                live_len=live),
            donate_argnums=2, static_argnums=6)
    return jax.jit(
        lambda p, e, c, i, nv, live: M.prefill_chunk(
            cfg, opts, p, e, c, i, n_valid=nv, live_len=live),
        donate_argnums=2, static_argnums=5)


@functools.lru_cache(maxsize=None)
def _jit_tick(cfg: ModelConfig, opts: ModelOptions, tick_tokens: int,
              eos: int, temperature: float, top_k: int,
              stop_on_finish: bool):
    return jax.jit(functools.partial(_fused_tick, cfg, opts, tick_tokens,
                                     eos, temperature, top_k,
                                     stop_on_finish))


@functools.lru_cache(maxsize=None)
def _jit_spec_tick(cfg: ModelConfig, opts: ModelOptions, tick_tokens: int,
                   spec_k: int, draft_blocks: int, eos: int,
                   stop_on_finish: bool, max_seq: int):
    """Speculative fused tick, jitted per engine signature. ``live_len``
    (first arg, static) is the banded verify key bound — the engine rounds
    it to whole bands, so it takes at most ``max_seq / prefill_band``
    distinct values. Dense engines pass ``page_table=None`` (an empty
    pytree, not a trace problem)."""
    return jax.jit(functools.partial(_fused_spec_tick, cfg, opts,
                                     tick_tokens, spec_k, draft_blocks, eos,
                                     stop_on_finish, max_seq),
                   static_argnums=0)


class ServingEngine:
    def __init__(self, cfg: ModelConfig, opts: ModelOptions, params,
                 n_slots: int = 4, max_seq: int = 512, eos: int = 1,
                 prompt_len: int = 64, fused: bool = True,
                 tick_tokens: int = 8, temperature: float = 0.0,
                 top_k: int = 0, seed: int = 0, stop_on_finish: bool = True,
                 paged: bool = False, page_size: int = 16,
                 num_pages: Optional[int] = None, prefix_cache: bool = True,
                 kv_dtype: str = "bf16", chunked_prefill: bool = False,
                 chunk_size: int = 32, token_budget: int = 64,
                 reserve_pages: Optional[int] = None,
                 spec_decode: bool = False, spec_k: int = 4,
                 draft_layers: Optional[int] = None,
                 draft_quant: Optional[str] = None,
                 scale_granularity: Optional[str] = None,
                 slo_hz: float = 0.0, mesh: Optional[Mesh] = None):
        if tick_tokens < 1:
            raise ValueError(f"tick_tokens must be >= 1, got {tick_tokens}")
        if mesh is not None:
            # sharded serving: every device stage becomes one shard_map-ped
            # program over the 'model' axis (see _init_mesh_stages). The
            # host-side scheduler/pool layer never sees the mesh.
            if "model" not in mesh.axis_names:
                raise ValueError("ServingEngine mesh needs a 'model' axis "
                                 "(launch.mesh.make_serving_mesh)")
            if any(mesh.shape[a] != 1 for a in mesh.axis_names
                   if a != "model"):
                raise ValueError("ServingEngine shards over 'model' only; "
                                 "every other mesh axis must have size 1")
            if cfg.encoder is not None:
                raise ValueError("mesh serving does not support "
                                 "encoder-decoder models (cross-attention "
                                 "context has no serving shard rule)")
            if not all(cfg.is_attn_layer(i) for i in range(cfg.num_layers)):
                raise ValueError("mesh serving requires attention-only "
                                 "decoders (SSM state has no head axis to "
                                 "partition the cache on)")
            if cfg.num_experts:
                raise ValueError("mesh serving does not support MoE layers "
                                 "(expert-parallel serving is not wired "
                                 "into the shard_map program)")
        if slo_hz < 0:
            raise ValueError(f"slo_hz must be >= 0, got {slo_hz}")
        if slo_hz > 0 and not chunked_prefill:
            raise ValueError("slo_hz requires chunked_prefill=True: the SLO "
                             "controller steers the per-tick decode depth "
                             "and chunk quota, which only exist under the "
                             "token-budget scheduler")
        if kv_quant.quant_dtype(kv_dtype) is not None and not paged:
            raise ValueError("kv_dtype quantization requires paged=True "
                             "(the page pool is the quantization boundary)")
        if chunked_prefill:
            if not fused:
                raise ValueError("chunked_prefill requires the fused decode "
                                 "path (fused=True)")
            if opts.window_cache:
                raise ValueError("chunked_prefill and window_cache ring "
                                 "buffers are mutually exclusive (rings "
                                 "don't support positioned prefill)")
            if cfg.encoder is not None:
                raise ValueError("chunked_prefill does not support "
                                 "encoder-decoder models")
            if not all(cfg.is_attn_layer(i) for i in range(cfg.num_layers)):
                raise ValueError("chunked_prefill requires attention-only "
                                 "decoders (SSM prefill state is not "
                                 "chunk-resumable yet)")
            if paged and chunk_size % page_size:
                raise ValueError(f"chunk_size {chunk_size} must divide by "
                                 f"page_size {page_size} so chunk writes "
                                 f"start page-aligned")
            if paged and opts.use_pallas and page_size != opts.prefill_band:
                # the paged chunk kernel partitions the key axis per page
                # while the dense kernel (monolithic prefill) partitions per
                # prefill_band; the chunked==monolithic bit-equality
                # contract needs one absolute partition on the kernel path
                raise ValueError(
                    f"chunked_prefill with paged=True and use_pallas "
                    f"requires page_size ({page_size}) == "
                    f"ModelOptions.prefill_band ({opts.prefill_band}): the "
                    f"paged chunk-prefill kernel blocks the key axis per "
                    f"page, and bit-equality across chunkings needs the "
                    f"same partition as the dense kernel's bands")
        self.spec_decode, self.spec_k = spec_decode, spec_k
        self.draft_blocks = self.draft_layers = 0
        self.draft_quant = draft_quant
        if spec_decode:
            if not fused:
                raise ValueError("spec_decode requires the fused decode "
                                 "path (fused=True)")
            if temperature > 0:
                raise ValueError("spec_decode is greedy-only: longest-"
                                 "prefix acceptance re-emits the verifier's "
                                 "argmax, which only matches the reference "
                                 "stream at temperature 0")
            if spec_k < 1:
                raise ValueError(f"spec_k must be >= 1, got {spec_k}")
            if opts.window_cache:
                raise ValueError("spec_decode and window_cache ring buffers "
                                 "are mutually exclusive (rings don't "
                                 "support positioned chunk writes)")
            if cfg.encoder is not None:
                raise ValueError("spec_decode does not support "
                                 "encoder-decoder models")
            if not all(cfg.is_attn_layer(i) for i in range(cfg.num_layers)):
                raise ValueError("spec_decode requires attention-only "
                                 "decoders (SSM state cannot roll back "
                                 "rejected drafts by position re-write)")
            if paged and opts.use_pallas and page_size != opts.prefill_band:
                raise ValueError(
                    f"spec_decode with paged=True and use_pallas requires "
                    f"page_size ({page_size}) == ModelOptions.prefill_band "
                    f"({opts.prefill_band}): the verify pass runs the paged "
                    f"chunk-prefill kernel, whose key-axis partition must "
                    f"match the dense kernel's bands for the bit-equality "
                    f"contract (same constraint as chunked_prefill)")
            period, nblocks, _ = stack_plan(cfg)
            if draft_layers is None:
                self.draft_blocks = max(1, nblocks // 2)
            else:
                if draft_layers % period or not (
                        0 < draft_layers <= nblocks * period):
                    raise ValueError(
                        f"draft_layers must be a multiple of the stack "
                        f"period ({period}) in 1..{nblocks * period}, "
                        f"got {draft_layers}")
                self.draft_blocks = draft_layers // period
            self.draft_layers = self.draft_blocks * period
            if draft_quant not in (None, "none", "int8", "fp8"):
                raise ValueError(f"draft_quant must be None/'none'/'int8'/"
                                 f"'fp8', got {draft_quant!r}")
        quantized = kv_quant.quant_dtype(kv_dtype) is not None
        if scale_granularity is not None and not quantized:
            raise ValueError("scale_granularity applies only to quantized "
                             "pools (kv_dtype int8/fp8)")
        if quantized:
            if scale_granularity is None:
                scale_granularity = "token" if spec_decode else "head"
            if scale_granularity not in kv_quant.SCALE_GRANULARITIES:
                raise ValueError(
                    f"scale_granularity must be one of "
                    f"{kv_quant.SCALE_GRANULARITIES}, "
                    f"got {scale_granularity!r}")
            if spec_decode and scale_granularity == "head":
                raise ValueError(
                    "spec_decode on a quantized pool requires "
                    "scale_granularity='token': shared per-(page, head) "
                    "scales let a rejected draft row's amax requantize "
                    "accepted rows on the same page, so speculative streams "
                    "cannot stay bit-equal to the per-token reference "
                    "(see docs/speculative.md)")
        self.scale_granularity = scale_granularity    # None when unquantized
        self.cfg, self.opts, self.params = cfg, opts, params
        self.mesh = mesh
        self._c1specs = None               # set by _init_mesh_stages
        self.n_slots, self.max_seq, self.eos = n_slots, max_seq, eos
        self.prompt_len = prompt_len
        self.fused, self.tick_tokens = fused, tick_tokens
        self.temperature, self.top_k = temperature, top_k
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.index = np.zeros(n_slots, np.int32)       # per-slot position
        self.budget = np.zeros(n_slots, np.int32)
        self.tokens = np.zeros((n_slots, 1), np.int32)
        self.paged, self.page_size = paged, page_size
        self.kv_dtype = kv_dtype
        self.prefix_cache = prefix_cache
        self.pool: Optional[KVPool] = None
        if paged:
            if max_seq % page_size:
                raise ValueError(f"max_seq {max_seq} must divide by "
                                 f"page_size {page_size}")
            pages_per_slot = max_seq // page_size
            if num_pages is None:
                # worst case every slot fills up, +1 for the null page
                num_pages = 1 + n_slots * pages_per_slot
            self.pool = KVPool(num_pages, page_size, n_slots, pages_per_slot)
            self.caches = M.init_caches(cfg, n_slots, max_seq, jnp.float32,
                                        opts, paged=True, num_pages=num_pages,
                                        page_size=page_size,
                                        kv_dtype=kv_dtype,
                                        scale_granularity=(scale_granularity
                                                           or "head"))
            self._bytes_per_page = sum(
                leaf.nbytes // num_pages for path, leaf in
                jax.tree_util.tree_leaves_with_path(self.caches)
                if is_paged_leaf(path))
        else:
            self.caches = M.init_caches(cfg, n_slots, max_seq, jnp.float32,
                                        opts)
            self._bytes_per_page = 0
        # per-device bytes per page: equals the summed figure on one device,
        # recomputed from actual shard buffers under a mesh
        self._bytes_per_page_shard = self._bytes_per_page
        self.stats = EngineStats()
        self.key = jax.random.PRNGKey(seed)
        self.scheduler: Optional[ChunkedScheduler] = None
        self.chunk_size, self.token_budget = chunk_size, token_budget
        # slot -> last time it made progress (chunk ran / tokens emitted);
        # the pool-aware admission policy evicts the longest-idle slot
        self._last_active = np.zeros(n_slots, np.float64)
        self.slo_hz = slo_hz
        self._slo = SLOController(slo_hz) if slo_hz > 0 else None
        if chunked_prefill:
            self.scheduler = ChunkedScheduler(chunk_size, token_budget)
            self._prefill_chunk = _jit_prefill_chunk(cfg, opts, paged)
        if paged:
            # decode headroom: admission never grabs the last pages an
            # in-flight decode needs to grow into (pool-aware policy)
            if reserve_pages is None:
                reserve_pages = n_slots if chunked_prefill else 0
            self.pool.set_reserve(min(reserve_pages,
                                      max(0, self.pool.num_pages - 2)))

        self._decode = _jit_decode(cfg, opts)
        self._prefill = _jit_prefill(cfg, opts, max_seq)
        self._vision = (_jit_vision(cfg, opts)
                        if cfg.vision is not None else None)
        self._tick = _jit_tick(cfg, opts, tick_tokens, eos, temperature,
                               top_k, stop_on_finish)
        # cache-maintenance stages behind instance indirection so every call
        # site (admission, COW, scale resets) is layout-agnostic; a mesh
        # swaps these for shard_map-ped equivalents below
        self._scatter_slot_fn = _scatter_slot
        self._scatter_pages_fn = (
            lambda c, c1, d: _scatter_pages(c, c1, d, self.page_size))
        self._copy_pages_fn = _copy_pages
        self._reset_scales_fn = _reset_page_scales
        self._spec_tick = None
        if spec_decode:
            # the weight-quantized draft shares the tree structure (and
            # dtypes) of params — fake quantization round-trips values only
            self.draft_params = (
                kv_quant.fake_quantize_tree(params, draft_quant)
                if draft_quant in ("int8", "fp8") else params)
            self._spec_tick = _jit_spec_tick(cfg, opts, tick_tokens, spec_k,
                                             self.draft_blocks, eos,
                                             stop_on_finish, max_seq)
        if mesh is not None:
            self._init_mesh_stages(mesh, stop_on_finish)

    # -- sharded serving (mesh) -------------------------------------------
    def _init_mesh_stages(self, mesh: Mesh, stop_on_finish: bool):
        """Rebind every device stage as a single shard_map-ped program over
        the mesh's ``model`` axis, and partition params + KV pool across it.

        Layout (Megatron-style tensor parallelism, serving_rules):

        - attention heads and KV-cache pages shard on the head axis: each
          device owns ``[num_pages, page_size, K/n, h]`` slices of every
          page, so the paged kernels run *unchanged* per shard and the
          host-side page tables stay global (replicated operands). GQA
          divisibility is atomic — smollm's 9/3 heads replicate over
          model=2/4 and the program is collective-free for them.
        - MLP width and vocab shard per-leaf; partial attention/MLP outputs
          psum inside the layer (layers.attention / layers.mlp) and the
          *only* all-gather sits at the lm head, right before sampling
          (model._logits) — the activation wire cost per decoded token is
          2 psums/layer + one [V] gather.
        - everything the host scheduler/pool touches (page tables, token
          state, budgets) is replicated, so scheduler/kv_pool code observes
          no mesh at all.

        ``check_rep=False`` everywhere: jax 0.4.x has no replication rule
        for ``lax.while_loop``, which both fused ticks are built on."""
        cfg, opts = self.cfg, self.opts
        rules = serving_rules(mesh.shape["model"], cfg.num_heads,
                              cfg.num_kv_heads)
        self._serving_rule_table = rules
        shopts = dataclasses.replace(opts, shard_axis="model")

        def specs_of(template):
            return jax.tree_util.tree_map(
                lambda s: spec_for(s.shape, s.axes, mesh, rules),
                template, is_leaf=is_pspec)

        templ = M.model_template(cfg)
        pspecs = specs_of(templ)
        # towers (vision / action head) run as plain einsum stacks with no
        # collective insertion — their params must stay whole per shard
        for k in ("vision", "encoder", "action_dit"):
            if k in pspecs:
                pspecs[k] = jax.tree_util.tree_map(
                    lambda s: P(), templ[k], is_leaf=is_pspec)
        if self.paged:
            cspecs = specs_of(cache_template(
                cfg, self.n_slots, self.max_seq, jnp.float32, opts,
                paged=True, num_pages=self.pool.num_pages,
                page_size=self.page_size, kv_dtype=self.kv_dtype,
                scale_granularity=(self.scale_granularity or "head")))
        else:
            cspecs = specs_of(cache_template(cfg, self.n_slots, self.max_seq,
                                             jnp.float32, opts))
        # batch-1 dense cache (prefill output / chunked-prefill carry)
        c1specs = specs_of(cache_template(cfg, 1, self.max_seq, jnp.float32,
                                          opts))
        self._c1specs = c1specs

        def place(tree, specs):
            return jax.tree_util.tree_map(
                lambda x, sp: jax.device_put(x, NamedSharding(mesh, sp)),
                tree, specs)

        self.params = place(self.params, pspecs)
        self.caches = place(self.caches, cspecs)
        if self.spec_decode:
            self.draft_params = place(self.draft_params, pspecs)
        if self.paged:
            # honest per-device accounting: measure the shard buffers, so a
            # head-replication fallback (or replicated scale rows) reports
            # its true per-device cost instead of an assumed 1/N
            self._bytes_per_page_shard = sum(
                leaf.addressable_shards[0].data.nbytes // self.pool.num_pages
                for path, leaf in
                jax.tree_util.tree_leaves_with_path(self.caches)
                if is_paged_leaf(path))
        self.stats.mesh_shape = tuple(
            (a, int(mesh.shape[a])) for a in mesh.axis_names)

        R = P()

        def smap(f, in_specs, out_specs):
            return shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_rep=False)

        self._decode = jax.jit(smap(
            lambda p, t, c, i, pt: M.decode_step(cfg, shopts, p, t, c, i,
                                                 page_table=pt),
            (pspecs, R, cspecs, R, R), (R, cspecs)))
        # monolithic prefill: the prefill-from-zero path must not allocate
        # its own caches inside the shard trace (init_caches would build the
        # global head count), so a reusable sharded zero tree rides along
        self._cache1_zeros = place(
            M.init_caches(cfg, 1, self.max_seq, jnp.float32, opts), c1specs)
        prefill_sharded = jax.jit(smap(
            lambda p, b, c0: M.prefill(cfg, shopts, p, b, self.max_seq,
                                       cache_dtype=jnp.float32,
                                       fresh_caches=c0),
            (pspecs, R, c1specs), (R, c1specs)))
        self._prefill = lambda p, b: prefill_sharded(p, b,
                                                     self._cache1_zeros)
        self._tick = jax.jit(smap(
            functools.partial(_fused_tick, cfg, shopts, self.tick_tokens,
                              self.eos, self.temperature, self.top_k,
                              stop_on_finish),
            (pspecs, R, cspecs, R, R, R, R, R, R),
            (R, cspecs, R, R, R, R, R, R, R)))
        if self.spec_decode:
            def spec_tick(live_len, p, dp, t, c, i, b, d, ms, pt):
                f = functools.partial(
                    _fused_spec_tick, cfg, shopts, self.tick_tokens,
                    self.spec_k, self.draft_blocks, self.eos,
                    stop_on_finish, self.max_seq, live_len)
                return smap(f, (pspecs, pspecs, R, cspecs, R, R, R, R, R),
                            (R, cspecs, R, R, R, R, R, R, R, R))(
                    p, dp, t, c, i, b, d, ms, pt)
            self._spec_tick = jax.jit(spec_tick, static_argnums=0)
        if self.scheduler is not None:
            if self.paged:
                def prefill_chunk(p, e, c, i, nv, pt, live):
                    f = lambda p, e, c, i, nv, pt: M.prefill_chunk(
                        cfg, shopts, p, e, c, i, n_valid=nv, page_table=pt,
                        live_len=live)
                    return smap(f, (pspecs, R, cspecs, R, R, R),
                                (R, cspecs))(p, e, c, i, nv, pt)
                self._prefill_chunk = jax.jit(prefill_chunk,
                                              donate_argnums=2,
                                              static_argnums=6)
            else:
                def prefill_chunk(p, e, c, i, nv, live):
                    f = lambda p, e, c, i, nv: M.prefill_chunk(
                        cfg, shopts, p, e, c, i, n_valid=nv, live_len=live)
                    return smap(f, (pspecs, R, c1specs, R, R),
                                (R, c1specs))(p, e, c, i, nv)
                self._prefill_chunk = jax.jit(prefill_chunk,
                                              donate_argnums=2,
                                              static_argnums=5)

        def scatter_slot(c, c1, slot, skip_paged):
            return smap(lambda a, b: _scatter_slot(a, b, slot, skip_paged),
                        (cspecs, c1specs), cspecs)(c, c1)
        self._scatter_slot_fn = jax.jit(scatter_slot, static_argnums=(2, 3))
        if self.paged:
            page_size = self.page_size
            self._scatter_pages_fn = jax.jit(smap(
                lambda c, c1, d: _scatter_pages_impl(c, c1, d, page_size),
                (cspecs, c1specs, R), cspecs), donate_argnums=0)
            self._copy_pages_fn = jax.jit(
                smap(_copy_pages_impl, (cspecs, R, R), cspecs),
                donate_argnums=0)
            self._reset_scales_fn = jax.jit(
                smap(_reset_page_scales_impl, (cspecs, R), cspecs),
                donate_argnums=0)

    def _fresh_cache1(self):
        """Zeroed batch-1 dense cache for one chunked-prefill admission.
        Dense chunks donate their cache carry, so each admission needs its
        own tree (the monolithic path's zeros are reusable — prefill there
        is non-donating)."""
        c = M.init_caches(self.cfg, 1, self.max_seq, jnp.float32, self.opts)
        if self.mesh is not None:
            c = jax.tree_util.tree_map(
                lambda x, sp: jax.device_put(
                    x, NamedSharding(self.mesh, sp)), c, self._c1specs)
        return c

    def _sample_host(self, logits):
        """Host-path sampling (admission + reference step) with the same
        temperature/top_k config the fused tick uses; greedy by default."""
        if self.temperature <= 0:
            return S.greedy(logits)
        self.key, sub = jax.random.split(self.key)
        return S.sample_token(logits, sub, self.temperature, self.top_k)

    # -- queue -----------------------------------------------------------
    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        # the relative SLO becomes absolute at submit: everything
        # downstream (EDF ordering, the SLO controller, attainment
        # scoring) compares wall clock against this one stamp
        req.t_deadline = (req.t_submit + req.deadline_s
                          if req.deadline_s > 0 else math.inf)
        if self.scheduler is not None:
            self.scheduler.submit(req)
        else:
            # legacy queue shares the class-ordered insert, so realtime
            # requests get admission priority in _admit too
            insert_by_class(self.queue, req)

    @property
    def pending(self) -> int:
        """Requests not yet finished: queued + mid-prefill + in slots."""
        n = len(self.queue) + sum(r is not None for r in self.slots)
        if self.scheduler is not None:
            n += self.scheduler.pending
        return n

    def cancel(self, uid: int) -> bool:
        """Abort request ``uid`` mid-flight, wherever it is in the pipeline.

        Call between ticks (the front-end stages cancellations and drains
        them at tick boundaries — never while a tick is in flight in another
        thread). Three cases, each leaving the engine in the same state as
        if the request had never been admitted past that point:

        - **queued** (waiting list / legacy queue): removed, nothing else
          held.
        - **mid-prefill** (chunked mode, a live ``PrefillTask``): the task
          is dropped without requeue and the slot's pool pages are freed.
          Full prompt pages the aborted chunks already registered in the
          prefix cache are *retained* (refcount 0, LRU) — their KV is
          written and correct, so a later identical observation still hits.
        - **mid-decode** (live slot): the slot is cleared and its pages are
          freed. The slot's table row resets to the null page, so if a
          fused tick is already compiled against the old snapshot the stale
          writes sink harmlessly (same mechanism as a finished slot riding
          through a tick).

        The request is marked ``cancelled`` and is *not* appended to
        ``finished``; pool accounting (``pages_in_use``) returns to what it
        was before the request was admitted, minus retained cache pages.
        Returns whether the uid was found live anywhere."""
        if self.scheduler is not None:
            for k, r in enumerate(self.scheduler.waiting):
                if r.uid == uid:
                    self.scheduler.waiting.pop(k)
                    r.cancelled = True
                    return True
            for s, t in list(self.scheduler.tasks.items()):
                if t.req.uid == uid:
                    self.scheduler.tasks.pop(s)
                    if self.paged:
                        self.pool.free_slot(s)
                        self._update_cache_stats()
                    t.req.cancelled = True
                    return True
        else:
            for k, r in enumerate(self.queue):
                if r.uid == uid:
                    self.queue.pop(k)
                    r.cancelled = True
                    return True
        for s in range(self.n_slots):
            req = self.slots[s]
            if req is not None and req.uid == uid:
                self.slots[s] = None
                if self.paged:
                    self.pool.free_slot(s)
                    self._update_cache_stats()
                req.cancelled = True
                return True
        return False

    # -- paged bookkeeping ------------------------------------------------
    def _prefix_page_keys(self, req: Request, n_prefix: int) -> List[bytes]:
        """Prefix-closed digests for ``req``'s full prompt pages (see the
        module-level ``prefix_page_keys`` — same function, engine config
        baked in). Empty when the prefix cache is disabled."""
        if not self.prefix_cache:
            return []
        return prefix_page_keys(self.cfg.name, self.page_size, self.kv_dtype,
                                req.prompt, req.patches, n_prefix)

    def _update_cache_stats(self):
        st, pool = self.stats, self.pool
        st.pages_in_use = pool.pages_in_use
        st.pages_hwm = max(st.pages_hwm, pool.pages_hwm)
        # the pool tracks page indices only; bytes-per-page is the engine's
        # layout knowledge (KVPool.byte_stats keeps the pool mesh-blind)
        st.cache_bytes_hwm = max(
            st.cache_bytes_hwm,
            pool.byte_stats(self._bytes_per_page)["bytes_in_use"])
        st.cache_bytes_hwm_shard = max(
            st.cache_bytes_hwm_shard,
            pool.byte_stats(self._bytes_per_page_shard)["bytes_in_use"])
        st.prefix_hits = pool.prefix_hits

    def _page_table_device(self):
        """Page table for the *decode* tick. The fused tick issues cache
        writes for every row, done or not — done rows sink into the null
        page because ``free_slot`` nulled them. A mid-prefill slot's row is
        live, though (its chunks need it), and the decode tick must not let
        that slot's stale index clobber freshly-written chunk KV: its row is
        nulled in the decode snapshot only."""
        pt = self.pool.page_table
        if self.scheduler is not None and self.scheduler.tasks:
            pt = pt.copy()
            for s in self.scheduler.tasks:
                pt[s, :] = 0
        return jnp.asarray(pt)

    def _slot_req(self, s: int) -> Optional[Request]:
        """The request occupying slot ``s`` — decoding or mid-prefill."""
        if self.slots[s] is not None:
            return self.slots[s]
        if self.scheduler is not None and s in self.scheduler.tasks:
            return self.scheduler.tasks[s].req
        return None

    def _preempt_slot(self, s: int):
        """Evict a live slot under pool pressure: free its pages and requeue
        the request from scratch. Works on both a decoding slot and a
        mid-prefill slot (chunked mode) — in the latter case the in-flight
        chunks are discarded; pages its first attempt registered in the
        prefix cache may still be retained, so the retry can prefix-skip
        what it already computed. Under greedy sampling the regenerated
        stream is identical (deterministic), so correctness is preserved;
        under temperature sampling the retried stream may differ (the
        degraded mode of an under-provisioned pool, not a crash)."""
        self.pool.free_slot(s)
        if self.slots[s] is not None:
            req = self.slots[s]
            self.slots[s] = None
            req.out_tokens = []
            self.stats.record_preemption(req)
            if self.scheduler is not None:
                self.scheduler.submit(req, front=True)
            else:
                insert_by_class(self.queue, req, front=True)
        elif self.scheduler is not None:
            task = self.scheduler.requeue_task(s)
            if task is not None:
                self.stats.record_preemption(task.req)

    def _evict_longest_idle(self, exclude: int = -1) -> bool:
        """Pool-aware admission policy: instead of blindly deferring on
        ``PoolExhausted``, preempt the longest-idle *queued-behind* slot —
        a prefill task already stalled on pool pressure. Only stalled tasks
        are candidates: decoders and progressing prefills free their pages
        by finishing, so evicting them would trade guaranteed progress for
        a restart (and two mutually-starved slots could ping-pong-evict
        each other forever). Candidates are additionally class-filtered
        (``scheduler.eviction_victims``): realtime prefill is never a
        victim — realtime never preempts realtime, and best-effort
        preempting realtime would be priority inversion. Returns whether
        a victim was evicted."""
        if self.scheduler is None:
            return False
        cands = eviction_victims(self.scheduler.tasks, exclude=exclude)
        if not cands:
            return False
        self._preempt_slot(min(cands, key=lambda s: self._last_active[s]))
        return True

    def _ensure_pages(self, steps: int, extra: int = 0):
        """Pre-allocate pages covering every position the next tick may
        write (index .. index+steps-1 per live slot), and copy-on-write any
        shared page in that range (none in normal engine flow — admission
        only shares full prompt pages — but enforced, not assumed).
        ``extra`` covers positions written but not necessarily *kept*: the
        speculative tick writes up to ``spec_k - 1`` draft/verify rows past
        the last accepted token, so its rounds need ``extra = spec_k - 1``
        backing pages beyond the budget-capped emit range (rows past
        ``max_seq`` are masked to the null sink instead and need none).

        Pool pressure degrades instead of crashing: if growth fails, the
        live slot holding the most pages (excluding the one being grown) is
        preempted and retried later; a single request the pool cannot hold
        at all is a sizing error and raises.

        Quantized pools: pages handed out by growth may have been freed by
        an earlier request and still carry its scale rows; those rows are
        zeroed on device before the tick, so the monotone-amax write policy
        starts from a clean scale and quantization stays history-independent
        (the admission path needs no reset — ``_scatter_pages`` overwrites
        scale rows wholesale)."""
        copies = []
        held_before: Dict[int, set] = {}
        for s in range(self.n_slots):
            if self.slots[s] is None:
                continue
            held_before[s] = set(self.pool.slot_pages[s])
            start = int(self.index[s])
            # never reserve past the slot's remaining budget — backing pages
            # a finishing slot cannot write could preempt a healthy one
            end = min(start + min(steps, max(int(self.budget[s]), 1)) + extra,
                      self.max_seq)
            while True:
                try:
                    self.pool.ensure(s, end)
                    copies += self.pool.prepare_write(s, start, end)
                    break
                except PoolExhausted:
                    victims = [v for v in range(self.n_slots)
                               if v != s
                               and (self.slots[v] is not None
                                    or (self.scheduler is not None
                                        and v in self.scheduler.tasks))]
                    if not victims:
                        raise PoolExhausted(
                            f"KV pool too small for a single request "
                            f"(slot {s} needs pages for {end} positions)")
                    # class preference: best-effort work yields first;
                    # realtime is only ever preempted here when nothing
                    # else can free pages (the no-deadlock fallback —
                    # decode growth must make progress or the pool is
                    # simply too small for the realtime working set)
                    be = [v for v in victims
                          if not is_realtime(self._slot_req(v))]
                    self._preempt_slot(max(
                        be or victims,
                        key=lambda v: len(self.pool.slot_pages[v])))
            self.slots[s].pages_used = len(self.pool.slot_pages[s])
        # pages a slot gained this call (growth and COW destinations;
        # diffed against entry so pages appended by an ensure() that
        # then raised are included too). Scale rows are zeroed *before*
        # the COW copy below, which restores the destinations' scales.
        self._reset_fresh_scales(sorted(
            {p for s, held in held_before.items()
             if self.slots[s] is not None
             for p in self.pool.slot_pages[s]
             if p not in held}))
        self._dispatch_copies(copies)
        self._update_cache_stats()

    def _dispatch_copies(self, copies: List):
        """Materialize copy-on-write (src, dst) page pairs with one jitted
        gather/scatter (zero-padded pairs are null->null no-ops)."""
        if not copies:
            return
        width = self.pool.pages_per_slot * self.n_slots
        src = np.zeros(width, np.int32)
        dst = np.zeros(width, np.int32)
        for i, (a, b) in enumerate(copies):
            src[i], dst[i] = a, b
        self.caches = self._copy_pages_fn(self.caches, jnp.asarray(src),
                                          jnp.asarray(dst))

    def _clamped_budget(self, req: Request, pos: int) -> int:
        """Clamp generation to cache capacity: decode writes at positions
        pos..pos+budget-1, which must stay < max_seq in *both* layouts
        (unclamped, each layout clamps its scatter differently and the
        bit-equality contract breaks). Warns when the clamp bites."""
        budget = min(req.max_tokens - 1, self.max_seq - pos)
        if budget < req.max_tokens - 1:
            warnings.warn(
                f"request {req.uid}: max_tokens {req.max_tokens} "
                f"exceeds cache capacity (prompt {pos} + budget > "
                f"max_seq {self.max_seq}); clamping",
                RuntimeWarning, stacklevel=2)
        return budget

    def _finish_slot(self, s: int, now: float):
        req = self.slots[s]
        req.done = True
        req.t_done = now
        self.stats.record_deadline(req)
        if self.paged:
            req.pages_used = len(self.pool.slot_pages[s])
            self.pool.free_slot(s)
            self._update_cache_stats()
        self.finished.append(req)
        self.slots[s] = None

    def _admit(self):
        """Monolithic (admit-stall) admission: pop the queue head into every
        free slot, running its *whole* prompt through one prefill dispatch.

        Per admitted request, in order: (1) capacity check —
        ``KVPool.can_admit`` over the prompt pages *plus the first decode
        write* must pass before anything is paid for (a deferred request
        must not waste a vision pass); (2) vision, as its own jitted stage
        so phase accounting survives; (3) batch-1 prefill + first-token
        sample (the TTFT boundary); (4) page allocation + page-wise scatter
        (paged) or batch-row scatter (dense). A request that already
        finishes at prefill (EOS first token / ``max_tokens <= 1`` / no
        cache headroom) never takes a slot — the inner loop retries the
        same slot with the next queued request.

        Atomicity under pool races: ``can_admit`` ran before vision+prefill,
        but a retained cache page can be reclaimed in between, so a raising
        ``admit`` rolls back every stat this attempt recorded (queue/TTFT
        samples, prefill token and key-lane counters) and requeues the
        request at the front — the retry must not double-count."""
        for s in range(self.n_slots):
            # the inner loop retries the slot when a request already finishes
            # at prefill (EOS first token, or max_tokens == 1)
            while self.slots[s] is None and self.queue:
                req = self.queue[0]
                n_prefix = (self.cfg.vision.num_tokens
                            if req.patches is not None and self._vision
                            else 0)
                pos = n_prefix + len(req.prompt)
                keys = (self._prefix_page_keys(req, n_prefix)
                        if self.paged else [])
                # capacity must cover the prompt AND the first decode write
                # at position pos (requests finishing at prefill need none)
                need = (0 if req.max_tokens <= 1
                        else min(pos + 1, self.max_seq))
                if self.paged and need and not self.pool.can_admit(need,
                                                                   keys):
                    if not any(r is not None for r in self.slots):
                        # nothing in flight will ever free pages: sizing error
                        raise PoolExhausted(
                            f"KV pool ({self.pool.num_pages - 1} pages) too "
                            f"small for request {req.uid} "
                            f"({self.pool.num_pages_for(need)} pages)")
                    # defer *before* paying for vision + prefill; retry when
                    # a finishing slot frees pages
                    return
                self.queue.pop(0)
                t0 = time.perf_counter()
                req.queue_s = t0 - req.t_submit
                self.stats.queue_s.append(req.queue_s)
                batch = {"tokens": jnp.asarray(req.prompt[None, :])}
                if n_prefix:
                    prefix = self._vision(self.params,
                                          jnp.asarray(req.patches[None]))
                    prefix.block_until_ready()
                    t1 = time.perf_counter()
                    self.stats.vision_time += t1 - t0
                    batch["prefix"] = prefix
                    t0 = t1
                logits, cache1 = self._prefill(self.params, batch)
                tok = int(self._sample_host(logits)[0])
                self.stats.prefill_syncs += 1
                req.t_prefill = time.perf_counter()
                self.stats.prefill_time += req.t_prefill - t0
                self.stats.prefill_tokens += pos
                # monolithic prefill attends the banded live prefix too
                # (model.prefill derives live_len from the prompt shape)
                self.stats.prefill_key_lanes += pos * band_len(
                    pos, self.opts.prefill_band, self.max_seq)
                self.stats.prefill_key_lanes_full += pos * self.max_seq
                req.ttft_s = req.t_prefill - req.t_submit
                self.stats.ttft_s.append(req.ttft_s)
                req.out_tokens.append(tok)
                budget = self._clamped_budget(req, pos)
                if tok == self.eos or req.max_tokens <= 1 or budget <= 0:
                    req.done = True
                    req.t_done = req.t_prefill
                    self.stats.record_deadline(req)
                    self.finished.append(req)
                    continue
                if self.paged:
                    try:
                        pages, n_shared = self.pool.admit(s, pos, keys)
                    except PoolExhausted:
                        # can_admit() raced a cached-page eviction; defer
                        # and roll the attempt's stats back too, so the
                        # retry doesn't double-count this request
                        self.queue.insert(0, req)
                        req.out_tokens.pop()
                        self.stats.queue_s.pop()
                        self.stats.ttft_s.pop()
                        self.stats.prefill_tokens -= pos
                        self.stats.prefill_key_lanes -= pos * band_len(
                            pos, self.opts.prefill_band, self.max_seq)
                        self.stats.prefill_key_lanes_full -= pos * self.max_seq
                        return
                    req.pages_used = len(pages)
                    req.pages_shared = n_shared
                    # shared pages already hold this prefix's KV — route
                    # their rows to the null sink instead of re-writing
                    dest = np.zeros(self.pool.pages_per_slot, np.int32)
                    dest[n_shared:len(pages)] = pages[n_shared:]
                    self.caches = self._scatter_pages_fn(self.caches, cache1,
                                                         jnp.asarray(dest))
                    self.caches = self._scatter_slot_fn(self.caches, cache1,
                                                        s, True)
                    self._update_cache_stats()
                else:
                    self.caches = self._scatter_slot_fn(self.caches, cache1,
                                                        s, False)
                self.index[s] = pos
                self.budget[s] = budget
                self.tokens[s, 0] = tok
                self.slots[s] = req
                self._last_active[s] = req.t_prefill

    # -- one engine tick ---------------------------------------------------
    def step(self) -> int:
        """Reference path: one decode step, one host sync per token."""
        if self.scheduler is not None:
            raise RuntimeError("chunked_prefill engines tick via "
                               "step_fused()/run() (fused only)")
        t_tick = time.perf_counter()
        pf0 = self.stats.prefill_tokens
        kl0 = self.stats.prefill_key_lanes
        self._admit()
        active = [s for s in range(self.n_slots) if self.slots[s] is not None]
        if not active:
            self.stats.tick_prefill_tokens.append(
                self.stats.prefill_tokens - pf0)
            self.stats.tick_key_lanes.append(
                self.stats.prefill_key_lanes - kl0)
            wall = time.perf_counter() - t_tick
            self.stats.tick_s.append(wall)
            self.stats.record_tick_wall(wall)
            return 0
        pt = None
        if self.paged:
            self._ensure_pages(1)
            pt = self._page_table_device()
            # growth may have preempted a slot under pool pressure
            active = [s for s in range(self.n_slots)
                      if self.slots[s] is not None]
        t0 = time.perf_counter()
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self.tokens), self.caches,
            jnp.asarray(self.index), pt)
        nxt = np.asarray(self._sample_host(logits))
        now = time.perf_counter()
        self.stats.decode_syncs += 1
        self.stats.ticks += 1
        self.stats.device_steps += 1
        self.stats.tokens_decoded += len(active)
        self.stats.decode_time += now - t0
        self.stats.decode_tick_s.append(now - t0)
        for s in active:
            req = self.slots[s]
            tok = int(nxt[s])
            req.out_tokens.append(tok)
            self._last_active[s] = now
            self.index[s] += 1
            self.budget[s] -= 1
            if tok == self.eos or self.budget[s] <= 0:
                self._finish_slot(s, now)
            else:
                self.tokens[s, 0] = tok
        self.stats.tick_prefill_tokens.append(
            self.stats.prefill_tokens - pf0)
        self.stats.tick_key_lanes.append(
            self.stats.prefill_key_lanes - kl0)
        wall = time.perf_counter() - t_tick
        self.stats.tick_s.append(wall)
        self.stats.record_tick_wall(wall)
        return len(active)

    def step_fused(self) -> int:
        """Fused path: up to ``tick_tokens`` decode steps per host sync.
        With ``chunked_prefill`` the tick additionally packs prefill chunks
        under the token budget (see ``_tick_chunked``)."""
        if self.scheduler is not None:
            return self._tick_chunked()
        t_tick = time.perf_counter()
        pf0 = self.stats.prefill_tokens
        kl0 = self.stats.prefill_key_lanes
        self._admit()
        emitted = self._decode_tick(self.tick_tokens)
        self.stats.tick_prefill_tokens.append(
            self.stats.prefill_tokens - pf0)
        self.stats.tick_key_lanes.append(
            self.stats.prefill_key_lanes - kl0)
        wall = time.perf_counter() - t_tick
        self.stats.tick_s.append(wall)
        self.stats.record_tick_wall(wall)
        return emitted

    def _decode_tick(self, max_steps: int) -> int:
        """The fused decode stage of one tick: up to ``max_steps`` (<= the
        compiled ``tick_tokens`` bound) device steps, one host sync. In
        scheduler mode ``max_steps`` is the planned per-slot token cap —
        with ``spec_decode`` it bounds *accepted* tokens, not passes, so
        the scheduler's token-budget accounting holds unchanged (a verify
        pass that would overshoot the cap has its emit clamped)."""
        active = [s for s in range(self.n_slots) if self.slots[s] is not None]
        if not active:
            return 0
        pt = None
        if self.paged:
            self._ensure_pages(min(max_steps, self.tick_tokens),
                               extra=self.spec_k - 1 if self.spec_decode
                               else 0)
            pt = self._page_table_device()
            # growth may have preempted a slot under pool pressure
            active = [s for s in range(self.n_slots)
                      if self.slots[s] is not None]
            if not active:
                return 0
        if self.spec_decode:
            return self._decode_tick_spec(max_steps, active, pt)
        t0 = time.perf_counter()
        done0 = np.asarray([self.slots[s] is None
                            for s in range(self.n_slots)])
        (tokens, self.caches, index, budget, done, self.key, out, n_emit,
         steps) = self._tick(
            self.params, jnp.asarray(self.tokens), self.caches,
            jnp.asarray(self.index), jnp.asarray(self.budget),
            jnp.asarray(done0), self.key,
            jnp.asarray(max_steps, jnp.int32), pt)
        out_h, n_emit_h, idx_h, bud_h, done_h, tok_h, steps_h = \
            jax.device_get((out, n_emit, index, budget, done, tokens, steps))
        now = time.perf_counter()
        self.stats.decode_syncs += 1
        self.stats.ticks += 1
        self.stats.device_steps += int(steps_h)
        self.stats.decode_time += now - t0
        self.stats.decode_tick_s.append(now - t0)
        self.index = np.array(idx_h, np.int32)
        self.budget = np.array(bud_h, np.int32)
        self.tokens = np.array(tok_h, np.int32)
        emitted = 0
        for s in active:
            req = self.slots[s]
            k = int(n_emit_h[s])
            req.out_tokens.extend(int(t) for t in out_h[s, :k])
            emitted += k
            if k:
                self._last_active[s] = now
            if done_h[s]:
                self._finish_slot(s, now)
        self.stats.tokens_decoded += emitted
        return emitted

    def _decode_tick_spec(self, max_steps: int, active: List[int],
                          pt) -> int:
        """Speculative decode stage: draft -> verify -> accept rounds on
        device (``_fused_spec_tick``), one host sync. Called by
        ``_decode_tick`` after page growth reserved ``spec_k - 1`` extra
        write rows per slot."""
        t0 = time.perf_counter()
        st = self.stats
        K = self.spec_k
        cap = int(min(max_steps, self.tick_tokens))
        idx0 = self.index.copy()
        done0 = np.asarray([self.slots[s] is None
                            for s in range(self.n_slots)])
        # per-slot static verify bounds (satellite: per-slot live bounds in
        # chunk dispatch): each slot's deepest verify row this tick is
        # index + cap + K - 2, so its banded key bound is independent of
        # the batch's oldest slot. The *dispatch* uses the collapsed max —
        # a per-slot tuple as a static jit argument would retrace per age
        # mix — while the per-slot bounds drive the key-lane accounting,
        # which is what the mixed-age over-attend ratio is measured from.
        bounds = {s: band_len(min(int(idx0[s]) + cap + K - 1, self.max_seq),
                              self.opts.prefill_band, self.max_seq)
                  for s in active}
        live_len = max(bounds.values())
        (tokens, self.caches, index, budget, done, out, e, hist, passes,
         iters) = self._spec_tick(
            live_len, self.params, self.draft_params,
            jnp.asarray(self.tokens), self.caches, jnp.asarray(self.index),
            jnp.asarray(self.budget), jnp.asarray(done0),
            jnp.asarray(max_steps, jnp.int32), pt)
        (out_h, e_h, idx_h, bud_h, done_h, tok_h, hist_h, passes_h,
         iters_h) = jax.device_get((out, e, index, budget, done, tokens,
                                    hist, passes, iters))
        now = time.perf_counter()
        st.decode_syncs += 1
        st.ticks += 1
        # one loop round == one full-model pass (the verify chunk), same
        # HBM-pass denomination as the plain fused tick's per-token steps
        st.device_steps += int(iters_h)
        vp = int(passes_h.sum())
        st.spec_verify_passes += vp
        st.spec_draft_steps += vp * (K - 1)
        st.spec_draft_pass_equiv += (vp * (K - 1) * self.draft_layers
                                     / max(1, self.cfg.num_layers))
        if len(st.spec_accept_hist) < K + 1:
            st.spec_accept_hist.extend(
                [0] * (K + 1 - len(st.spec_accept_hist)))
        for n, c in enumerate(hist_h):
            st.spec_accept_hist[n] += int(c)
        for s in active:
            st.spec_key_lanes += int(passes_h[s]) * K * bounds[s]
            st.spec_key_lanes_full += int(passes_h[s]) * K * self.max_seq
        st.decode_time += now - t0
        st.decode_tick_s.append(now - t0)
        self.index = np.array(idx_h, np.int32)
        self.budget = np.array(bud_h, np.int32)
        self.tokens = np.array(tok_h, np.int32)
        emitted = 0
        for s in active:
            req = self.slots[s]
            k = int(e_h[s])
            req.out_tokens.extend(int(t) for t in out_h[s, :k])
            emitted += k
            if k:
                self._last_active[s] = now
            if done_h[s]:
                self._finish_slot(s, now)
        st.tokens_decoded += emitted
        return emitted

    # -- chunked-prefill scheduler mode ------------------------------------
    def _reset_fresh_scales(self, fresh: List[int]):
        """Quantized pools: zero the scale rows of pages just handed to a
        slot, so the monotone-amax write policy starts clean instead of
        inheriting a dead request's range (history-independence)."""
        if not fresh or kv_quant.quant_dtype(self.kv_dtype) is None:
            return
        width = self.pool.pages_per_slot * self.n_slots
        ids = np.zeros(width, np.int32)     # 0-pads hit the null page
        ids[:len(fresh)] = fresh
        self.caches = self._reset_scales_fn(self.caches, jnp.asarray(ids))

    def _admit_chunked(self):
        """Admission in scheduler mode: assign waiting requests to free
        slots as *prefill tasks* (no prompt compute yet — chunks run under
        the tick budget). Paged pools allocate chunk-granularly: shared
        prefix pages plus the first chunk's pages now, the rest as chunks
        arrive (``ensure``), so a long prompt doesn't lock down its whole
        footprint before producing a single token. On a prefix-cache hit
        chunking starts at the first non-shared token — capped one position
        before the prompt end so the last-token logits are always computed —
        and the skipped positions are never recomputed."""
        sched = self.scheduler
        for s in range(self.n_slots):
            # inner loop: an eviction requeues its victim at the *front* of
            # the waiting queue, so the head must be re-read before this
            # slot admits (popping a stale head would drop the victim and
            # double-admit the request behind it)
            while (sched.waiting and self.slots[s] is None
                   and s not in sched.tasks):
                req = sched.waiting[0]
                n_prefix = (self.cfg.vision.num_tokens
                            if req.patches is not None and self._vision
                            else 0)
                total = n_prefix + len(req.prompt)
                if total > self.max_seq:
                    raise ValueError(
                        f"request {req.uid}: prompt ({total} positions) "
                        f"exceeds max_seq {self.max_seq}")
                n_skip = 0
                keys: List[bytes] = []
                if self.paged:
                    keys = self._prefix_page_keys(req, n_prefix)
                    n_hit = self.pool.match_prefix(keys)
                    # never skip the final position: its logits seed decode
                    skip_pages = min(n_hit, (total - 1) // self.page_size)
                    n_skip = skip_pages * self.page_size
                    first_len = min(total, n_skip + self.chunk_size)
                    need_total = min(
                        total + (0 if req.max_tokens <= 1 else 1),
                        self.max_seq)
                    # structural sizing check (drain limit: everything else
                    # eventually finishes and frees its pages, but the
                    # request's own holdings — shared hits included — still
                    # occupy capacity). Two ways a request can never
                    # complete, each a raise-now instead of stall-forever:
                    # absolute capacity must cover prompt + the first
                    # decode page, and — when any prefill page must be
                    # freshly allocated — the whole prompt footprint must
                    # fit the admission side, which cannot touch the decode
                    # headroom reserve (the decode page itself may).
                    usable = self.pool.num_pages - 1
                    prefill_pages = self.pool.num_pages_for(total)
                    if (self.pool.num_pages_for(need_total) > usable
                            or (prefill_pages - n_hit > 0 and prefill_pages
                                > usable - self.pool.reserve)):
                        raise PoolExhausted(
                            f"KV pool ({usable} pages, {self.pool.reserve} "
                            f"reserved) too small for request {req.uid} "
                            f"({prefill_pages} prompt pages, "
                            f"{n_hit} prefix-shared)")
                    if not self.pool.can_admit(first_len, keys):
                        in_flight = any(
                            self.slots[v] is not None or v in sched.tasks
                            for v in range(self.n_slots))
                        if not in_flight:
                            raise PoolExhausted(
                                f"KV pool cannot admit request {req.uid} "
                                f"with nothing in flight to free pages")
                        # pool-aware policy: evict the longest-idle stalled
                        # task and re-evaluate with the (possibly new) queue
                        # head; with no stalled victim, defer — something
                        # in flight is progressing and will free pages
                        if not self._evict_longest_idle():
                            return
                        continue
                    try:
                        pages, n_shared = self.pool.admit(s, first_len, keys,
                                                          register=False)
                        # recomputed positions may land in shared pages when
                        # the skip cap pulled below the hit run: COW them
                        copies = self.pool.prepare_write(s, n_skip, total)
                    except PoolExhausted:
                        self.pool.free_slot(s)
                        return
                    req.pages_shared = n_shared
                    self._reset_fresh_scales(list(pages[n_shared:])
                                             + [d for _, d in copies])
                    self._dispatch_copies(copies)
                    self._update_cache_stats()
                sched.waiting.pop(0)
                t0 = time.perf_counter()
                req.queue_s = t0 - req.t_submit
                self.stats.queue_s.append(req.queue_s)
                batch = {"tokens": jnp.asarray(req.prompt[None, :])}
                if n_prefix:
                    if n_skip < n_prefix:
                        prefix = self._vision(self.params,
                                              jnp.asarray(req.patches[None]))
                        prefix.block_until_ready()
                        t1 = time.perf_counter()
                        self.stats.vision_time += t1 - t0
                        t0 = t1
                    else:
                        # the whole vision prefix is prefix-cache shared:
                        # its KV already sits in pool pages, so the tower
                        # run itself is skipped (chunks never read these
                        # embedding rows)
                        prefix = jnp.zeros((1, n_prefix, self.cfg.d_model),
                                           jnp.float32)
                    batch["prefix"] = prefix
                embeds = M.embed_prompt(self.cfg, self.opts, self.params,
                                        batch)
                cache1 = None if self.paged else self._fresh_cache1()
                req.prefill_skipped = n_skip
                self.stats.prefill_skipped += n_skip
                sched.start_task(PrefillTask(req=req, slot=s, total=total,
                                             n_skip=n_skip, embeds=embeds,
                                             cache1=cache1, prefix_keys=keys,
                                             t_start=t0))
                self._last_active[s] = t0

    def _run_chunk(self, cp: ChunkPlan):
        """Execute one planned prefill chunk: grow pages to cover it (paged),
        pad the embedding slice to the static chunk shape, dispatch the
        jitted positioned prefill, and — on the final chunk — sample the
        request's first token and flip the slot to decoding."""
        task, s = cp.task, cp.task.slot
        t0 = time.perf_counter()
        if self.paged:
            end = cp.start + cp.n_tok
            held0 = set(self.pool.slot_pages[s])
            stalled = False
            try:
                self.pool.ensure(s, end, use_reserve=False)
            except PoolExhausted:
                # admission-side growth must not eat decode headroom: mark
                # the task stalled, try evicting another (longest-idle)
                # stalled task, else wait — in-flight decoders/prefills
                # free pages by finishing, and the stalled task retries
                # (deprioritized) every tick
                task.stalled = True
                stalled = True
                if self._evict_longest_idle(exclude=s):
                    try:
                        self.pool.ensure(s, end, use_reserve=False)
                        stalled = False
                    except PoolExhausted:
                        pass
            # scale-reset by diff against entry, not ensure()'s return: a
            # raising ensure() keeps its partial growth on the slot, and
            # those pages must lose their previous owner's scale rows even
            # on the stall path (the next tick's retry won't see them as
            # fresh again) — same invariant as _ensure_pages
            self._reset_fresh_scales(sorted(
                p for p in self.pool.slot_pages[s] if p not in held0))
            if stalled:
                return
            pt_row = jnp.asarray(self.pool.page_table[s:s + 1])
        emb = task.embeds
        chunk = jnp.zeros((1, self.chunk_size, emb.shape[-1]), emb.dtype)
        chunk = chunk.at[:, :cp.n_tok].set(
            emb[:, cp.start:cp.start + cp.n_tok])
        start = jnp.asarray(cp.start, jnp.int32)
        n_valid = jnp.asarray(cp.n_tok, jnp.int32)
        # banded key-axis bound: the chunk attends the live prefix
        # [0, start + n_tok) rounded up to whole bands — a static jit arg
        # with at most max_seq / prefill_band distinct values, vs the old
        # full-max_seq cache view every chunk paid for
        live = band_len(cp.start + cp.n_tok, self.opts.prefill_band,
                        self.max_seq)
        if self.paged:
            logits, self.caches = self._prefill_chunk(
                self.params, chunk, self.caches, start, n_valid, pt_row,
                live)
            self.pool.register_prefix_pages(s, task.prefix_keys or (),
                                            cp.start + cp.n_tok)
            self._update_cache_stats()
        else:
            logits, task.cache1 = self._prefill_chunk(
                self.params, chunk, task.cache1, start, n_valid, live)
        self.stats.prefill_key_lanes += self.chunk_size * live
        self.stats.prefill_key_lanes_full += self.chunk_size * self.max_seq
        task.pos = cp.start + cp.n_tok
        task.stalled = False
        self.stats.prefill_tokens += cp.n_tok
        self._last_active[s] = time.perf_counter()
        if task.pos >= task.total:
            self._finish_prefill(task, logits)
        self.stats.prefill_time += time.perf_counter() - t0

    def _finish_prefill(self, task: PrefillTask, logits):
        """Last chunk done: sample the first token (TTFT boundary) from the
        chunk's last-valid-position logits [B,1,V] and either finish the
        request outright (EOS / max_tokens<=1 / no cache headroom) or hand
        the slot to the decode stage."""
        req, s = task.req, task.slot
        pos = task.total
        tok = int(self._sample_host(logits)[0])
        self.stats.prefill_syncs += 1
        now = time.perf_counter()
        req.t_prefill = now
        req.ttft_s = now - req.t_submit
        self.stats.ttft_s.append(req.ttft_s)
        req.out_tokens.append(tok)
        budget = self._clamped_budget(req, pos)
        self.scheduler.finish_task(s)
        if tok == self.eos or req.max_tokens <= 1 or budget <= 0:
            req.done = True
            req.t_done = now
            self.stats.record_deadline(req)
            if self.paged:
                req.pages_used = len(self.pool.slot_pages[s])
                self.pool.free_slot(s)
                self._update_cache_stats()
            self.finished.append(req)
            return
        if self.paged:
            req.pages_used = len(self.pool.slot_pages[s])
        else:
            self.caches = self._scatter_slot_fn(self.caches, task.cache1, s,
                                                False)
            task.cache1 = None
        self.index[s] = pos
        self.budget[s] = budget
        self.tokens[s, 0] = tok
        self.slots[s] = req
        self._last_active[s] = now

    def _tick_chunked(self) -> int:
        """One scheduler tick: admit waiting requests into prefill tasks,
        pack chunks + decode under the token budget, run the chunks, then
        the (budget-capped) fused decode stage. See docs/scheduler.md for
        the tick anatomy.

        Stage order and the invariants each stage hands the next:

        1. **Admit** (``_admit_chunked``): every free slot without a task
           gets one, pages for shared prefix + first chunk allocated. After
           this, ``scheduler.tasks`` names exactly the mid-prefill slots.
        2. **Plan** (``ChunkedScheduler.plan_tick``): pure policy over host
           state — decode reservation first
           (``decode_steps = clamp(budget // n_active, 1, tick_tokens)``),
           then FCFS chunks into the remainder. ``n_active`` is read
           *before* chunks run, so a prefill finishing mid-tick joins this
           same tick's decode stage without shrinking anyone's reservation.
        3. **Chunks** (``_run_chunk`` per plan entry): each entry is
           validated against live state first — the task may have been
           preempted/finished by an earlier entry this tick, or an earlier
           chunk of the same task may have stalled on pool pressure
           (``cp.task.pos != cp.start`` — positions must be written in
           order, so the successor chunk is dropped and replanned next
           tick rather than leaving a hole in the cache).
        4. **Decode** (``_decode_tick(plan.decode_steps)``): the fused tick
           capped at the planned depth — a dynamic operand, so the budget
           never recompiles the loop.

        Per-tick stats appended here (``tick_prefill_tokens``,
        ``tick_key_lanes``, ``tick_s``) are the head-of-line metrics the
        scheduler bench gates on: no tick's prefill may exceed the token
        budget."""
        t_tick = time.perf_counter()
        pf0 = self.stats.prefill_tokens
        kl0 = self.stats.prefill_key_lanes
        sched = self.scheduler
        self._admit_chunked()
        n_active = sum(r is not None for r in self.slots)
        slo = None
        if self._slo is not None:
            # the deadline check: remaining work + slack per realtime
            # decoding slot, plus whether realtime prefill is still in the
            # pipe, against the measured tick EWMA (see SLOController)
            rt_decode = [(int(self.budget[s]), req_deadline(self.slots[s]))
                         for s in range(self.n_slots)
                         if self.slots[s] is not None
                         and is_realtime(self.slots[s])]
            rt_prefill = (any(is_realtime(t.req)
                              for t in sched.tasks.values())
                          or any(is_realtime(r) for r in sched.waiting))
            slo = self._slo.plan(t_tick, self.stats.tick_ewma_s,
                                 rt_decode, rt_prefill)
        plan = sched.plan_tick(n_active, self.tick_tokens, slo=slo)
        for cp in plan.chunks:
            if sched.tasks.get(cp.task.slot) is not cp.task:
                continue    # finished or preempted earlier this tick
            if cp.task.pos != cp.start:
                continue    # an earlier chunk of this task stalled
            self._run_chunk(cp)
        emitted = 0
        if n_active:
            emitted = self._decode_tick(plan.decode_steps)
        elif plan.chunks:
            self.stats.ticks += 1
        self.stats.tick_prefill_tokens.append(
            self.stats.prefill_tokens - pf0)
        self.stats.tick_key_lanes.append(
            self.stats.prefill_key_lanes - kl0)
        wall = time.perf_counter() - t_tick
        self.stats.tick_s.append(wall)
        self.stats.record_tick_wall(wall)
        return emitted

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        """Drive ticks until the queue and slots drain, or ``max_ticks`` is
        hit. A hit tick budget is surfaced (warning + ``pending`` count)
        rather than silently returning partial work."""
        step = self.step_fused if self.fused else self.step
        ticks = 0
        while self.pending and ticks < max_ticks:
            step()
            ticks += 1
        if self.pending:
            queued = (len(self.scheduler.waiting) if self.scheduler
                      else len(self.queue))
            # Surface the phase/queue/TTFT decomposition alongside the count:
            # a stalled fleet is diagnosed from this one line (growing
            # queue_p99 with flat decode percentiles = admission starvation;
            # the reverse = the decode path itself slowed down).
            ph = self.stats.phase_report()
            diag = (f"phases vision={ph['vision']:.3f}s "
                    f"prefill={ph['prefill']:.3f}s "
                    f"decode={ph['decode']:.3f}s")
            for k in ("queue_p50", "queue_p99", "ttft_p50", "ttft_p99",
                      "decode_tick_p99"):
                if k in ph:
                    diag += f"; {k}={ph[k]:.4f}s"
            warnings.warn(
                f"ServingEngine.run: tick budget ({max_ticks}) exhausted "
                f"with {self.pending} requests pending "
                f"({queued} queued, "
                f"{sum(r is not None for r in self.slots)} in flight; "
                f"{diag})",
                RuntimeWarning, stacklevel=2)
        return self.finished


def _path_keys(path):
    """Pytree path -> hashable tuple of dict keys (for cross-tree lookups:
    a quantized paged cache has scale leaves the dense prefill cache lacks,
    so the two trees cannot be tree_map'd jointly)."""
    return tuple(getattr(p, "key", p) for p in path)


def _scatter_slot(caches, cache1, slot: int, skip_paged: bool = False):
    """Copy a batch-1 prefill cache into slot `slot` of the slot caches.
    The batch axis of every leaf comes from the cache pytree's explicit
    annotation (stacks.cache_batch_axis): block caches are layer-stacked, so
    batch sits at axis 1; tail caches carry it at axis 0. With
    ``skip_paged`` the pool-layout leaves (attention k/v and their scale
    siblings) are left untouched — they are filled by ``_scatter_pages``.
    Leaves are matched across the two trees by path key, because the
    quantized slot cache carries scale leaves the dense prefill cache
    doesn't have."""
    flat1 = {_path_keys(p): leaf for p, leaf
             in jax.tree_util.tree_leaves_with_path(cache1)}

    def scatter(path, big):
        if skip_paged and is_paged_leaf(path):
            return big
        small = flat1[_path_keys(path)]
        axis = cache_batch_axis(path)
        assert small.shape[axis] == 1, (path, small.shape, axis)
        idx = [slice(None)] * big.ndim
        idx[axis] = slice(slot, slot + 1)
        return big.at[tuple(idx)].set(small.astype(big.dtype))
    return jax.tree_util.tree_map_with_path(scatter, caches)


def _scatter_pages_impl(caches, cache1, dest_pages, page_size: int):
    """Scatter a batch-1 dense prefill cache into pool pages, quantizing on
    the way in when the pool stores int8/fp8 codes.

    ``dest_pages`` [pages_per_slot] int32 holds the physical destination for
    each prompt page; entries routed to 0 (the null page) are write sinks —
    used both for prefix-shared pages (already holding identical KV) and for
    pages past the slot's allocation.

    Quantized pools: each prompt page's scale is its amax / qmax at the
    pool's granularity — per (page, KV head) or per token row, inferred
    from the scale leaf's shape — computed from the fp32 prefill KV,
    written to the sibling ``k_scale``/``v_scale`` leaf for the same
    destination pages, and used to encode the value rows. Decode writes
    into the tail page later grow a "head" scale monotonically, or replace
    a "token" row outright (see layers.update_cache_paged)."""
    flat_big, treedef = jax.tree_util.tree_flatten_with_path(caches)
    big_by_key = {_path_keys(p): leaf for p, leaf in flat_big}
    flat1 = {_path_keys(p): leaf for p, leaf
             in jax.tree_util.tree_leaves_with_path(cache1)}

    def page_rows(keys, stacked):
        """Dense prefill leaf -> page-major rows [(nb,) P, ps, K, h]."""
        small = flat1[keys]
        if stacked:                   # blocks: [nb, 1, S, K, h]
            nb, _, seq = small.shape[:3]
            return small.reshape(nb, seq // page_size, page_size,
                                 *small.shape[3:])
        seq = small.shape[1]          # tail: [1, S, K, h]
        return small.reshape(seq // page_size, page_size, *small.shape[2:])

    out = []
    for path, big in flat_big:
        if not is_paged_leaf(path):
            out.append(big)
            continue
        keys = _path_keys(path)
        stacked = cache_batch_axis(path) == 1
        # scale and value leaves both derive from one quantize_page_rows
        # call on the same dense rows (XLA CSEs the duplicate), so the
        # stored scales can never diverge from the scales the codes were
        # encoded under
        if is_scale_leaf(path):
            vkey = keys[:-1] + ("k" if keys[-1] == "k_scale" else "v",)
            gran = ("token" if big.ndim == (4 if stacked else 3)
                    else "head")       # leaf shape encodes the granularity
            _, scale = kv_quant.quantize_page_rows(page_rows(vkey, stacked),
                                                   big_by_key[vkey].dtype,
                                                   gran)
            out.append(big.at[:, dest_pages].set(scale) if stacked
                       else big.at[dest_pages].set(scale))
            continue
        rows = page_rows(keys, stacked)
        if kv_quant.is_quantized(big.dtype):
            sc = big_by_key[keys[:-1] + (keys[-1] + "_scale",)]
            gran = "token" if sc.ndim == (4 if stacked else 3) else "head"
            rows, _ = kv_quant.quantize_page_rows(rows, big.dtype, gran)
        out.append(big.at[:, dest_pages].set(rows.astype(big.dtype))
                   if stacked else
                   big.at[dest_pages].set(rows.astype(big.dtype)))
    return jax.tree_util.tree_unflatten(treedef, out)


# jitted single-device entry points; the sharded engine wraps the raw impls
# in shard_map instead (per-shard bodies are unchanged — the K axis of every
# paged leaf is untouched by page scatter/copy/reset)
_scatter_pages = functools.partial(
    jax.jit, static_argnames=("page_size",),
    donate_argnums=0)(_scatter_pages_impl)


def _reset_page_scales_impl(caches, page_ids):
    """Zero the quantization-scale rows of ``page_ids`` (padded with 0 — the
    null page, harmless to reset). Run on pages entering a slot via decode
    growth, whose previous owner's scale rows would otherwise leak into the
    monotone-amax write policy and make quantization history-dependent."""
    def reset(path, big):
        if not is_scale_leaf(path):
            return big
        if cache_batch_axis(path) == 1:   # blocks: [nb, P, K]
            return big.at[:, page_ids].set(0.0)
        return big.at[page_ids].set(0.0)
    return jax.tree_util.tree_map_with_path(reset, caches)


_reset_page_scales = functools.partial(
    jax.jit, donate_argnums=0)(_reset_page_scales_impl)


def _copy_pages_impl(caches, src_pages, dst_pages):
    """Device-side page copies for copy-on-write: page dst <- page src for
    every pair (padding pairs are 0 -> 0, a null-page no-op)."""
    def copy(path, big):
        if not is_paged_leaf(path):
            return big
        if cache_batch_axis(path) == 1:   # blocks: [nb, P, ps, K, h]
            return big.at[:, dst_pages].set(big[:, src_pages])
        return big.at[dst_pages].set(big[src_pages])
    return jax.tree_util.tree_map_with_path(copy, caches)


_copy_pages = functools.partial(
    jax.jit, donate_argnums=0)(_copy_pages_impl)
