"""Continuous-batching serving engine.

Decode runs as one jitted step over a fixed slot batch [B_slots]; each slot
carries its own cache position (per-slot `index` vector — see
layers.update_cache / attention_decode). Finished slots are refilled from
the request queue via a jitted prefill whose cache slice is scattered into
the slot cache. This is vLLM-style continuous batching re-expressed in fixed
shapes (the XLA-friendly formulation): no recompilation on admit/evict.

Phase latency accounting (vision / prefill / decode) is recorded per request
— the serving-side counterpart of the paper's Nsight phase decomposition.
"""
from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.serving import sampler as S


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [S] int32
    max_tokens: int
    patches: Optional[np.ndarray] = None
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_prefill: float = 0.0
    t_done: float = 0.0


class ServingEngine:
    def __init__(self, cfg: ModelConfig, opts: ModelOptions, params,
                 n_slots: int = 4, max_seq: int = 512, eos: int = 1,
                 prompt_len: int = 64):
        self.cfg, self.opts, self.params = cfg, opts, params
        self.n_slots, self.max_seq, self.eos = n_slots, max_seq, eos
        self.prompt_len = prompt_len
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.index = np.zeros(n_slots, np.int32)       # per-slot position
        self.budget = np.zeros(n_slots, np.int32)
        self.tokens = np.zeros((n_slots, 1), np.int32)
        self.caches = M.init_caches(cfg, n_slots, max_seq, jnp.float32, opts)

        self._decode = jax.jit(
            lambda p, t, c, i: M.decode_step(cfg, opts, p, t, c, i))
        self._prefill = jax.jit(
            lambda p, b: M.prefill(cfg, opts, p, b, max_seq,
                                   cache_dtype=jnp.float32))

    # -- queue -----------------------------------------------------------
    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _admit(self):
        for s in range(self.n_slots):
            if self.slots[s] is None and self.queue:
                req = self.queue.pop(0)
                batch = {"tokens": jnp.asarray(req.prompt[None, :])}
                if req.patches is not None:
                    batch["patches"] = jnp.asarray(req.patches[None])
                logits, cache1 = self._prefill(self.params, batch)
                req.t_prefill = time.perf_counter()
                tok = int(S.greedy(logits)[0])
                req.out_tokens.append(tok)
                n_prefix = (self.cfg.vision.num_tokens
                            if self.cfg.vision is not None and req.patches is not None else 0)
                pos = n_prefix + len(req.prompt)
                self.caches = _scatter_slot(self.caches, cache1, s)
                self.index[s] = pos
                self.budget[s] = req.max_tokens - 1
                self.tokens[s, 0] = tok
                self.slots[s] = req

    # -- one engine tick ---------------------------------------------------
    def step(self) -> int:
        self._admit()
        active = [s for s in range(self.n_slots) if self.slots[s] is not None]
        if not active:
            return 0
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self.tokens), self.caches,
            jnp.asarray(self.index))
        nxt = np.asarray(S.greedy(logits))
        for s in active:
            req = self.slots[s]
            tok = int(nxt[s])
            req.out_tokens.append(tok)
            self.index[s] += 1
            self.budget[s] -= 1
            if tok == self.eos or self.budget[s] <= 0:
                req.done = True
                req.t_done = time.perf_counter()
                self.finished.append(req)
                self.slots[s] = None
            else:
                self.tokens[s, 0] = tok
        return len(active)

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        ticks = 0
        while (self.queue or any(r is not None for r in self.slots)) \
                and ticks < max_ticks:
            self.step()
            ticks += 1
        return self.finished


def _scatter_slot(caches, cache1, slot: int):
    """Copy a batch-1 prefill cache into slot `slot` of the slot caches.
    Block caches carry batch in dim 1 (behind the stacked layer dim), tail
    caches in dim 0; we locate it as the first axis where the prefill cache
    has extent 1 and the slot cache doesn't match."""
    def scatter(big, small):
        axis = next(i for i in range(big.ndim)
                    if small.shape[i] == 1 and big.shape[i] != small.shape[i])
        idx = [slice(None)] * big.ndim
        idx[axis] = slice(slot, slot + 1)
        return big.at[tuple(idx)].set(small.astype(big.dtype))
    return jax.tree.map(scatter, caches, cache1)
