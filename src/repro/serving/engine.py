"""Continuous-batching serving engine with a device-resident decode loop.

Decode runs over a fixed slot batch [B_slots]; each slot carries its own
cache position (per-slot `index` vector — see layers.update_cache /
attention_decode). Finished slots are refilled from the request queue via a
jitted prefill whose cache slice is scattered into the slot cache. This is
vLLM-style continuous batching re-expressed in fixed shapes (the
XLA-friendly formulation): no recompilation on admit/evict.

Two decode paths:

- **fused** (default): one jitted multi-token tick — a ``lax.while_loop``
  over up to ``tick_tokens`` decode steps that carries per-slot
  index/budget/done state as device arrays and fuses sampling into the step.
  The host is consulted only when a slot finishes or the tick's token budget
  is exhausted, so an N-token decode costs ~ceil(N/K) host syncs instead of
  N. This attacks exactly the launch/sync overhead the paper identifies as
  first-order for the memory-bound action-generation phase.
- **reference**: the original one-token-per-tick path (``step()``), kept for
  equivalence testing and as the bit-exactness oracle under greedy sampling.

Phase latency accounting (vision / prefill / decode) is recorded per request
and aggregated in ``EngineStats`` — the serving-side counterpart of the
paper's Nsight phase decomposition — and survives the fusion: vision runs as
its own jitted stage (``M.encode_vision`` feeding ``batch['prefix']``), and
decode wall-time is attributed per tick.
"""
from __future__ import annotations

import functools
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.models.stacks import cache_batch_axis
from repro.serving import sampler as S


@dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # [S] int32
    max_tokens: int
    patches: Optional[np.ndarray] = None
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False
    t_submit: float = 0.0
    t_prefill: float = 0.0
    t_done: float = 0.0


@dataclass
class EngineStats:
    """Host-sync contract + phase accounting for one engine lifetime.

    A "sync" is a device->host readback that blocks the Python loop (the
    per-token ``np.asarray``/``int()`` the paper's launch-overhead term maps
    to). The fused path pays one per tick; the reference path one per token.
    """
    decode_syncs: int = 0       # blocking readbacks on the decode path
    prefill_syncs: int = 0      # blocking readbacks at admission
    ticks: int = 0              # engine ticks (fused or reference)
    device_steps: int = 0       # decode steps executed on device
    tokens_decoded: int = 0     # tokens emitted by the decode path
    vision_time: float = 0.0
    prefill_time: float = 0.0
    decode_time: float = 0.0

    def phase_report(self) -> Dict[str, float]:
        """Figure-2-style wall-time decomposition."""
        return {"vision": self.vision_time, "prefill": self.prefill_time,
                "decode": self.decode_time}


def _fused_tick(cfg: ModelConfig, opts: ModelOptions, K: int, eos: int,
                temperature: float, top_k: int, stop_on_finish: bool,
                params, tokens, caches, index, budget, done, key):
    """Up to K decode steps on device. Per-slot carry: current token [B,1],
    cache position index [B], remaining budget [B], done [B]. Emitted tokens
    land in out [B,K] (each live slot fills a prefix of its row, length
    n_emit[s]). Exits early when every slot is done or — with
    ``stop_on_finish`` — as soon as any slot newly finishes, so the host can
    refill it."""
    B = tokens.shape[0]
    out0 = jnp.full((B, K), -1, jnp.int32)
    n_emit0 = jnp.zeros((B,), jnp.int32)
    entry_done = done

    def cond(c):
        step, _, _, _, _, done, _, _, _ = c
        go = (step < K) & ~jnp.all(done)
        if stop_on_finish:
            go &= ~jnp.any(done & ~entry_done)
        return go

    def body(c):
        step, tokens, caches, index, budget, done, key, out, n_emit = c
        logits, caches = M.decode_step(cfg, opts, params, tokens, caches,
                                       index)
        key, sub = jax.random.split(key)
        nxt = S.sample_token(logits, sub, temperature, top_k)   # [B]
        live = ~done
        col = jnp.where(live, nxt, -1)[:, None]
        out = jax.lax.dynamic_update_slice(out, col, (0, step))
        n_emit = n_emit + live.astype(jnp.int32)
        budget = jnp.where(live, budget - 1, budget)
        newly = live & ((nxt == eos) | (budget <= 0))
        index = jnp.where(live, index + 1, index)
        tokens = jnp.where(live[:, None], nxt[:, None], tokens)
        return (step + 1, tokens, caches, index, budget, done | newly, key,
                out, n_emit)

    init = (jnp.asarray(0, jnp.int32), tokens, caches, index, budget, done,
            key, out0, n_emit0)
    (steps, tokens, caches, index, budget, done, key, out, n_emit) = \
        jax.lax.while_loop(cond, body, init)
    return tokens, caches, index, budget, done, key, out, n_emit, steps


# Jitted stages are cached per static signature (configs are frozen
# dataclasses, hence hashable), so constructing many engines — tests, sweeps,
# one engine per model replica — shares compiled code instead of re-tracing.
@functools.lru_cache(maxsize=None)
def _jit_decode(cfg: ModelConfig, opts: ModelOptions):
    return jax.jit(lambda p, t, c, i: M.decode_step(cfg, opts, p, t, c, i))


@functools.lru_cache(maxsize=None)
def _jit_prefill(cfg: ModelConfig, opts: ModelOptions, max_seq: int):
    return jax.jit(lambda p, b: M.prefill(cfg, opts, p, b, max_seq,
                                          cache_dtype=jnp.float32))


@functools.lru_cache(maxsize=None)
def _jit_vision(cfg: ModelConfig, opts: ModelOptions):
    return jax.jit(lambda p, px: M.encode_vision(cfg, opts, p, px))


@functools.lru_cache(maxsize=None)
def _jit_tick(cfg: ModelConfig, opts: ModelOptions, tick_tokens: int,
              eos: int, temperature: float, top_k: int,
              stop_on_finish: bool):
    return jax.jit(functools.partial(_fused_tick, cfg, opts, tick_tokens,
                                     eos, temperature, top_k,
                                     stop_on_finish))


class ServingEngine:
    def __init__(self, cfg: ModelConfig, opts: ModelOptions, params,
                 n_slots: int = 4, max_seq: int = 512, eos: int = 1,
                 prompt_len: int = 64, fused: bool = True,
                 tick_tokens: int = 8, temperature: float = 0.0,
                 top_k: int = 0, seed: int = 0, stop_on_finish: bool = True):
        if tick_tokens < 1:
            raise ValueError(f"tick_tokens must be >= 1, got {tick_tokens}")
        self.cfg, self.opts, self.params = cfg, opts, params
        self.n_slots, self.max_seq, self.eos = n_slots, max_seq, eos
        self.prompt_len = prompt_len
        self.fused, self.tick_tokens = fused, tick_tokens
        self.temperature, self.top_k = temperature, top_k
        self.queue: List[Request] = []
        self.finished: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.index = np.zeros(n_slots, np.int32)       # per-slot position
        self.budget = np.zeros(n_slots, np.int32)
        self.tokens = np.zeros((n_slots, 1), np.int32)
        self.caches = M.init_caches(cfg, n_slots, max_seq, jnp.float32, opts)
        self.stats = EngineStats()
        self.key = jax.random.PRNGKey(seed)

        self._decode = _jit_decode(cfg, opts)
        self._prefill = _jit_prefill(cfg, opts, max_seq)
        self._vision = (_jit_vision(cfg, opts)
                        if cfg.vision is not None else None)
        self._tick = _jit_tick(cfg, opts, tick_tokens, eos, temperature,
                               top_k, stop_on_finish)

    def _sample_host(self, logits):
        """Host-path sampling (admission + reference step) with the same
        temperature/top_k config the fused tick uses; greedy by default."""
        if self.temperature <= 0:
            return S.greedy(logits)
        self.key, sub = jax.random.split(self.key)
        return S.sample_token(logits, sub, self.temperature, self.top_k)

    # -- queue -----------------------------------------------------------
    def submit(self, req: Request):
        req.t_submit = time.perf_counter()
        self.queue.append(req)

    def _admit(self):
        for s in range(self.n_slots):
            # the inner loop retries the slot when a request already finishes
            # at prefill (EOS first token, or max_tokens == 1)
            while self.slots[s] is None and self.queue:
                req = self.queue.pop(0)
                t0 = time.perf_counter()
                batch = {"tokens": jnp.asarray(req.prompt[None, :])}
                n_prefix = 0
                if req.patches is not None and self._vision is not None:
                    prefix = self._vision(self.params,
                                          jnp.asarray(req.patches[None]))
                    prefix.block_until_ready()
                    t1 = time.perf_counter()
                    self.stats.vision_time += t1 - t0
                    batch["prefix"] = prefix
                    n_prefix = self.cfg.vision.num_tokens
                    t0 = t1
                logits, cache1 = self._prefill(self.params, batch)
                tok = int(self._sample_host(logits)[0])
                self.stats.prefill_syncs += 1
                req.t_prefill = time.perf_counter()
                self.stats.prefill_time += req.t_prefill - t0
                req.out_tokens.append(tok)
                if tok == self.eos or req.max_tokens <= 1:
                    req.done = True
                    req.t_done = req.t_prefill
                    self.finished.append(req)
                    continue
                pos = n_prefix + len(req.prompt)
                self.caches = _scatter_slot(self.caches, cache1, s)
                self.index[s] = pos
                self.budget[s] = req.max_tokens - 1
                self.tokens[s, 0] = tok
                self.slots[s] = req

    # -- one engine tick ---------------------------------------------------
    def step(self) -> int:
        """Reference path: one decode step, one host sync per token."""
        self._admit()
        active = [s for s in range(self.n_slots) if self.slots[s] is not None]
        if not active:
            return 0
        t0 = time.perf_counter()
        logits, self.caches = self._decode(
            self.params, jnp.asarray(self.tokens), self.caches,
            jnp.asarray(self.index))
        nxt = np.asarray(self._sample_host(logits))
        now = time.perf_counter()
        self.stats.decode_syncs += 1
        self.stats.ticks += 1
        self.stats.device_steps += 1
        self.stats.tokens_decoded += len(active)
        self.stats.decode_time += now - t0
        for s in active:
            req = self.slots[s]
            tok = int(nxt[s])
            req.out_tokens.append(tok)
            self.index[s] += 1
            self.budget[s] -= 1
            if tok == self.eos or self.budget[s] <= 0:
                req.done = True
                req.t_done = now
                self.finished.append(req)
                self.slots[s] = None
            else:
                self.tokens[s, 0] = tok
        return len(active)

    def step_fused(self) -> int:
        """Fused path: up to ``tick_tokens`` decode steps per host sync."""
        self._admit()
        active = [s for s in range(self.n_slots) if self.slots[s] is not None]
        if not active:
            return 0
        t0 = time.perf_counter()
        done0 = np.asarray([self.slots[s] is None
                            for s in range(self.n_slots)])
        (tokens, self.caches, index, budget, done, self.key, out, n_emit,
         steps) = self._tick(
            self.params, jnp.asarray(self.tokens), self.caches,
            jnp.asarray(self.index), jnp.asarray(self.budget),
            jnp.asarray(done0), self.key)
        out_h, n_emit_h, idx_h, bud_h, done_h, tok_h, steps_h = \
            jax.device_get((out, n_emit, index, budget, done, tokens, steps))
        now = time.perf_counter()
        self.stats.decode_syncs += 1
        self.stats.ticks += 1
        self.stats.device_steps += int(steps_h)
        self.stats.decode_time += now - t0
        self.index = np.array(idx_h, np.int32)
        self.budget = np.array(bud_h, np.int32)
        self.tokens = np.array(tok_h, np.int32)
        emitted = 0
        for s in active:
            req = self.slots[s]
            k = int(n_emit_h[s])
            req.out_tokens.extend(int(t) for t in out_h[s, :k])
            emitted += k
            if done_h[s]:
                req.done = True
                req.t_done = now
                self.finished.append(req)
                self.slots[s] = None
        self.stats.tokens_decoded += emitted
        return emitted

    def run(self, max_ticks: int = 10_000) -> List[Request]:
        step = self.step_fused if self.fused else self.step
        ticks = 0
        while (self.queue or any(r is not None for r in self.slots)) \
                and ticks < max_ticks:
            step()
            ticks += 1
        return self.finished


def _scatter_slot(caches, cache1, slot: int):
    """Copy a batch-1 prefill cache into slot `slot` of the slot caches.
    The batch axis of every leaf comes from the cache pytree's explicit
    annotation (stacks.cache_batch_axis): block caches are layer-stacked, so
    batch sits at axis 1; tail caches carry it at axis 0."""
    def scatter(path, big, small):
        axis = cache_batch_axis(path)
        assert small.shape[axis] == 1, (path, small.shape, axis)
        idx = [slice(None)] * big.ndim
        idx[axis] = slice(slot, slot + 1)
        return big.at[tuple(idx)].set(small.astype(big.dtype))
    return jax.tree_util.tree_map_with_path(scatter, caches, cache1)
