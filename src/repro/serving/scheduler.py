"""Continuous-batching scheduler: chunked prefill under a per-tick token
budget (Sarathi-style), with prefill-from-position for prefix-cache hits.

The paper's bottleneck analysis makes decode ticks the scarce resource: the
memory-bound action-generation phase is where end-to-end latency lives, so
every tick an active decoder spends stalled behind a monolithic prompt
prefill is lost control-frequency budget. The legacy engine admits with
"admit, stall, decode": a new request runs its *whole* prompt through one
prefill dispatch while every live slot waits. This module replaces that with
a token-budget tick:

- Every prompt is split into fixed-size **prefill chunks** (``chunk_size``
  tokens, the jit-stable dispatch shape; a partial final chunk is padded and
  masked via ``n_valid``).
- Each tick packs work under ``token_budget`` tokens: active decode slots
  are served first (one token per slot per decode step — they are the
  latency-critical phase), then the remaining budget is given to prefill
  chunks FCFS. A long prompt therefore never blocks an active decoder for
  more than the token budget — it is spread over as many ticks as it needs.
- On a prefix-cache hit the request's first chunk starts at the first
  non-shared token (**prefill-from-position**): the shared pages' KV is
  already in the pool, chunks attend to it through the page table, and the
  shared fraction of prefill compute is genuinely skipped — not just its
  storage deduplicated.

This module is the *policy*: pure host-side bookkeeping with no jax
dependency, unit-testable without a model. The mechanism — running chunks,
scattering pages, sampling the first token — lives in
``serving.engine.ServingEngine`` (``chunked_prefill=True``). Budget math and
tick anatomy are documented in docs/scheduler.md.
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Optional, Tuple

# Priority classes. ``realtime`` models the paper's control loop: a robot
# that must receive its action chunk before the next observation lands.
# ``best_effort`` is everything else (episode starts, offline queries).
# The class is carried on the request object (``Request.priority`` /
# ``FleetRequest.priority``); policy code reads it through ``is_realtime``
# so plain test doubles without the attribute default to best-effort.
REALTIME = "realtime"
BEST_EFFORT = "best_effort"


def is_realtime(req: Any) -> bool:
    """Class of a request-like object (missing attribute = best-effort)."""
    return getattr(req, "priority", BEST_EFFORT) == REALTIME


def req_deadline(req: Any) -> float:
    """Absolute deadline (``t_submit + deadline_s``) of a request-like
    object; ``inf`` when it carries none — an undeadlined realtime request
    still outranks best-effort but sorts last within its class."""
    return getattr(req, "t_deadline", math.inf)


def insert_by_class(queue: List[Any], req: Any, front: bool = False):
    """Insert ``req`` into a waiting ``queue`` kept in admission order:
    one realtime segment at the head (EDF — earliest absolute deadline
    first, FCFS among equal deadlines), then the best-effort segment
    (FCFS). This is the single insertion policy shared by the chunked
    scheduler's waiting list and the legacy engine queue, so realtime
    admission priority holds on both paths.

    ``front=True`` restores seniority after a preemption or capacity
    deferral: a best-effort request re-enters at the head of *its own
    segment* (it can never leapfrog realtime work), a realtime request
    re-enters ahead of equal-deadline peers (its deadline already encodes
    its urgency). With no realtime requests anywhere this degrades exactly
    to ``append`` / ``insert(0)`` — the static FCFS order, bit for bit."""
    if is_realtime(req):
        dl = req_deadline(req)
        i = 0
        while i < len(queue) and is_realtime(queue[i]) and (
                req_deadline(queue[i]) < dl
                or (not front and req_deadline(queue[i]) == dl)):
            i += 1
        queue.insert(i, req)
        return
    if front:
        i = 0
        while i < len(queue) and is_realtime(queue[i]):
            i += 1
        queue.insert(i, req)
    else:
        queue.append(req)


def task_order_key(task: "PrefillTask") -> Tuple:
    """Chunk-priority key for ``plan_tick``: healthy before stalled, then
    realtime (EDF within class) before best-effort, then admission order.
    With no realtime tasks this reduces to the static ``(stalled, seq)``
    FCFS order — the bit-equality anchor."""
    rt = is_realtime(task.req)
    return (task.stalled, 0 if rt else 1,
            req_deadline(task.req) if rt else math.inf, task.seq)


def eviction_victims(tasks: Dict[int, "PrefillTask"],
                     exclude: int = -1) -> List[int]:
    """Slots whose in-flight prefill may be preempted to free pool pages:
    *stalled* (already queued-behind on pool pressure) *best-effort*
    tasks only. Realtime tasks are never victims — a realtime beneficiary
    must not preempt its own class (EDF already ordered them; evicting a
    peer trades one deadline for another), and a best-effort beneficiary
    evicting realtime would be priority inversion. The invariant the
    property suite checks: no call path ever selects a realtime victim."""
    return [s for s, t in tasks.items()
            if s != exclude and t.stalled and not is_realtime(t.req)]


@dataclass
class SLOTick:
    """Deadline context for one ``plan_tick`` call, produced by
    :class:`SLOController` from live engine state (never computed inside
    the scheduler — ``plan_tick`` stays a pure function of its inputs).

    ``decode_need`` is the per-slot decode depth realtime work requires
    this tick (0 = no realtime decode pressure; the static split already
    suffices). ``be_chunk_quota`` caps the prefill-chunk tokens
    best-effort tasks may take this tick (``None`` = no cap; ``0`` =
    realtime work is under pressure and best-effort prefill yields its
    whole quota — chunk dispatches are the tick's wall-time heavy stage,
    so shedding them is what actually shortens the next tick)."""
    decode_need: int = 0
    be_chunk_quota: Optional[int] = None


class SLOController:
    """Closes the loop from a latency SLO to per-tick budget decisions.

    The target is a control frequency (``slo_hz``, e.g. the paper's 10 Hz
    action rate): every realtime request must finish its action chunk
    before its absolute deadline. The controller converts that into this
    tick's knobs using the engine's per-tick EWMA wall time — the live
    measurement of what one tick costs end to end:

    - A realtime decoding slot with ``remaining`` tokens and ``slack``
      seconds has ``floor(slack / ewma)`` ticks left; it needs
      ``ceil(remaining / ticks_left)`` tokens per tick to make its
      deadline. ``decode_need`` is the max over realtime slots, so the
      fused decode stage (which runs all slots at one depth) is deep
      enough for the tightest deadline.
    - A slot is *under pressure* when its slack is less than ``safety``
      times the time it still needs at the measured tick rate; any
      realtime request still waiting or mid-prefill also counts as
      pressure (its deadline is burning in the queue). Under pressure
      best-effort prefill chunks are quota'd to zero for the tick.

    Host-side and jit-free, like the rest of the policy layer."""

    def __init__(self, slo_hz: float, safety: float = 2.0):
        if slo_hz <= 0:
            raise ValueError(f"slo_hz must be > 0, got {slo_hz}")
        self.slo_hz = slo_hz
        self.period_s = 1.0 / slo_hz
        self.safety = safety

    def plan(self, now: float, tick_ewma_s: float,
             rt_decode: Iterable[Tuple[int, float]],
             rt_prefill_pending: bool) -> SLOTick:
        """``rt_decode``: (remaining_tokens, absolute_deadline) per
        realtime decoding slot. ``rt_prefill_pending``: any realtime
        request waiting or mid-prefill."""
        ewma = max(float(tick_ewma_s), 1e-6)
        need = 0
        pressure = bool(rt_prefill_pending)
        for remaining, t_dl in rt_decode:
            remaining = int(remaining)
            if remaining <= 0 or not math.isfinite(t_dl):
                continue
            slack = t_dl - now
            ticks_left = max(1, int(slack / ewma))
            need = max(need, -(-remaining // ticks_left))
            if slack < self.safety * remaining * ewma:
                pressure = True
        return SLOTick(decode_need=need,
                       be_chunk_quota=0 if pressure else None)


@dataclass
class PrefillTask:
    """One request mid-prefill: admitted to a slot, pages allocated up to
    the next chunk, ``pos`` .. ``total`` still to run. ``n_skip`` prompt
    positions were served from the prefix cache and are never recomputed."""
    req: Any                    # serving.engine.Request
    slot: int
    total: int                  # n_prefix + len(prompt) positions
    n_skip: int = 0             # positions skipped via prefix-cache hit
    pos: int = 0                # next position to prefill (starts at n_skip)
    seq: int = 0                # admission order (FCFS tiebreak)
    embeds: Any = None          # [1, total, d] prompt embeddings (engine)
    cache1: Any = None          # dense engines: batch-1 prefill cache
    prefix_keys: Any = None     # paged engines: prefix-closed page digests
    t_start: float = 0.0        # prefill start (queue_s boundary)
    stalled: bool = False       # pool pressure on last attempt; cleared by
    #                             the next successful chunk. Stalled tasks
    #                             are planned last (healthy work first) and
    #                             are the only admission-side eviction
    #                             victims — a stalled task is by definition
    #                             queued-behind, while decoders and
    #                             progressing tasks free pages by finishing

    @property
    def remaining(self) -> int:
        return self.total - self.pos


@dataclass
class ChunkPlan:
    """One prefill-chunk dispatch: ``n_tok`` valid tokens of ``task``'s
    prompt starting at position ``start`` (padded to the engine's static
    chunk shape)."""
    task: PrefillTask
    start: int
    n_tok: int


@dataclass
class TickPlan:
    """What one engine tick executes: prefill chunks, then up to
    ``decode_steps`` fused decode steps for the active slots."""
    chunks: List[ChunkPlan] = field(default_factory=list)
    decode_steps: int = 0
    budget_used: int = 0


class ChunkedScheduler:
    """Token-budget continuous-batching policy.

    Budget math per tick (``plan_tick``):

    1. **Decode first.** ``n_active`` decoding slots reserve
       ``n_active * decode_steps`` tokens, with
       ``decode_steps = clamp(token_budget // n_active, 1, tick_tokens)``.
       Active decoders always advance at least one step — prefill pressure
       can slow decode to one token per tick but never stall it — and when
       the budget is generous they keep the engine's full fused-tick depth.
    2. **Chunks fill the remainder.** In-flight prefills (FCFS by admission
       order) take chunks of ``min(chunk_size, remaining prompt, remaining
       budget)`` valid tokens until the budget is spent. A task may receive
       several chunks in one tick on an idle engine; with zero leftover
       budget it simply waits (decoders free budget when they finish).
    3. **Progress floor.** With no active decoders the whole budget (>= 1
       token, enforced at construction) goes to prefill, so the head task
       always gets a chunk — even ``token_budget < chunk_size`` degrades to
       slow prefill, not deadlock.

    The scheduler owns the waiting queue and the in-flight task table; the
    engine owns slots, pools, and device state. ``stalled`` tasks (pool
    pressure on their last attempt) are planned after healthy tasks and
    retried every tick until pages free up or they are evicted.

    Invariants the engine relies on:

    - ``tasks`` is keyed by slot and a slot holds at most one in-flight
      prefill (asserted in ``start_task``); a slot is *either* decoding
      or mid-prefill, never both.
    - ``seq`` is monotone in admission order, so the FCFS tiebreak in
      ``plan_tick`` is stable across ticks — a task's chunk priority
      never changes while it is in flight.
    - ``waiting`` is class-ordered (realtime EDF segment, then
      best-effort FCFS — ``insert_by_class``); within a class arrival
      order is preserved except for ``front=True`` re-queues (preemption
      victims and admission-capacity deferrals keep their seniority).
    - ``plan_tick`` only *reads* scheduler state: planning a tick and
      then not executing it (or executing it partially under pool
      pressure) leaves nothing to roll back here — ``task.pos`` advances
      only when the engine reports the chunk ran.
    """

    def __init__(self, chunk_size: int, token_budget: int):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        if token_budget < 1:
            raise ValueError(f"token_budget must be >= 1, "
                             f"got {token_budget}")
        self.chunk_size = chunk_size
        self.token_budget = token_budget
        self.waiting: List[Any] = []            # Requests not yet admitted
        self.tasks: Dict[int, PrefillTask] = {}  # slot -> in-flight prefill
        self._seq = 0

    # -- queue / task lifecycle -------------------------------------------
    def submit(self, req, front: bool = False):
        """Queue a request for admission, class-ordered: realtime requests
        EDF at the head, best-effort FCFS behind (``insert_by_class``).
        ``front=True`` restores seniority within the request's own class
        (preempted / capacity-deferred requests re-enter at the head of
        their segment so they cannot be starved by a steady arrival
        stream)."""
        insert_by_class(self.waiting, req, front=front)

    @property
    def pending(self) -> int:
        """Requests this scheduler still owes work: waiting + mid-prefill.
        (Decoding slots are the engine's; the engine's own ``pending``
        adds them.)"""
        return len(self.waiting) + len(self.tasks)

    def start_task(self, task: PrefillTask) -> PrefillTask:
        """Admit a request into a slot: it now competes for chunk budget."""
        assert task.slot not in self.tasks, f"slot {task.slot} mid-prefill"
        task.seq = self._seq
        task.pos = task.n_skip
        self._seq += 1
        self.tasks[task.slot] = task
        return task

    def finish_task(self, slot: int) -> PrefillTask:
        """Prefill complete (or request finished at prefill): drop the
        task; the engine flips the slot to decoding."""
        return self.tasks.pop(slot)

    def requeue_task(self, slot: int) -> Optional[PrefillTask]:
        """Preemption: the slot's in-flight prefill is abandoned and its
        request goes back to the *front* of the waiting queue (it has
        seniority). Written chunks are discarded — on re-admission the
        prefix cache may still serve the pages the first attempt
        registered, so the retry can be cheaper than the original."""
        task = self.tasks.pop(slot, None)
        if task is not None:
            self.submit(task.req, front=True)
        return task

    # -- the per-tick policy ----------------------------------------------
    def plan_tick(self, n_active: int, tick_tokens: int,
                  slo: Optional[SLOTick] = None) -> TickPlan:
        """Pack one tick: decode reservation first, then prefill chunks
        class-ordered (realtime EDF, then best-effort FCFS) under what is
        left of ``token_budget``.

        With an :class:`SLOTick` context the deadline check runs before
        packing: the decode reservation deepens to ``slo.decode_need``
        when realtime decode is behind schedule (clamped to
        ``tick_tokens``; the reservation may then exceed ``token_budget``
        — the budget is the fairness policy, the deadline is the point,
        and the overdraw self-limits because chunks only pack into
        ``max(0, budget - reservation)``), and best-effort chunk tokens
        are capped at ``slo.be_chunk_quota`` (realtime tasks' chunks are
        never quota'd — their prefill is on the deadline path). With
        ``slo=None`` (or an all-best-effort workload) the plan is
        bit-identical to the static policy.

        The budget bounds *planned* work. A prefill that completes during
        this tick's chunk stage joins the same tick's decode stage (the
        engine re-reads the active set), adding up to ``decode_steps``
        unplanned decode tokens — deliberate: delaying that slot one tick
        would cost first-token latency to enforce an accounting nicety.

        ``decode_steps`` is denominated in *emitted tokens per slot*, not
        engine-loop iterations — the contract that keeps this policy
        mechanism-agnostic. The plain fused tick emits one token per loop
        step, so the two readings coincide; the speculative tick
        (``spec_decode=True``) emits a variable 1..spec_k accepted tokens
        per verify pass and clamps its emit count to this same cap, so a
        tick's decode stage never exceeds ``n_active * decode_steps``
        tokens regardless of how few HBM passes produced them."""
        plan = TickPlan()
        if n_active:
            plan.decode_steps = max(
                1, min(tick_tokens, self.token_budget // n_active))
            if slo is not None and slo.decode_need > plan.decode_steps:
                plan.decode_steps = min(tick_tokens, slo.decode_need)
        left = max(0, self.token_budget - n_active * plan.decode_steps)
        be_left = left
        if slo is not None and slo.be_chunk_quota is not None:
            be_left = min(be_left, slo.be_chunk_quota)
        # stalled tasks go last: healthy work first, but they still retry
        # every tick (their stall may clear the moment a decoder finishes)
        for task in sorted(self.tasks.values(), key=task_order_key):
            rt = is_realtime(task.req)
            pos = task.pos
            while (left if rt else min(left, be_left)) > 0 \
                    and pos < task.total:
                n = min(self.chunk_size, task.total - pos,
                        left if rt else min(left, be_left))
                plan.chunks.append(ChunkPlan(task, pos, n))
                pos += n
                left -= n
                if not rt:
                    be_left -= n
        plan.budget_used = (n_active * plan.decode_steps
                            + sum(c.n_tok for c in plan.chunks))
        return plan
