"""Continuous-batching scheduler: chunked prefill under a per-tick token
budget (Sarathi-style), with prefill-from-position for prefix-cache hits.

The paper's bottleneck analysis makes decode ticks the scarce resource: the
memory-bound action-generation phase is where end-to-end latency lives, so
every tick an active decoder spends stalled behind a monolithic prompt
prefill is lost control-frequency budget. The legacy engine admits with
"admit, stall, decode": a new request runs its *whole* prompt through one
prefill dispatch while every live slot waits. This module replaces that with
a token-budget tick:

- Every prompt is split into fixed-size **prefill chunks** (``chunk_size``
  tokens, the jit-stable dispatch shape; a partial final chunk is padded and
  masked via ``n_valid``).
- Each tick packs work under ``token_budget`` tokens: active decode slots
  are served first (one token per slot per decode step — they are the
  latency-critical phase), then the remaining budget is given to prefill
  chunks FCFS. A long prompt therefore never blocks an active decoder for
  more than the token budget — it is spread over as many ticks as it needs.
- On a prefix-cache hit the request's first chunk starts at the first
  non-shared token (**prefill-from-position**): the shared pages' KV is
  already in the pool, chunks attend to it through the page table, and the
  shared fraction of prefill compute is genuinely skipped — not just its
  storage deduplicated.

This module is the *policy*: pure host-side bookkeeping with no jax
dependency, unit-testable without a model. The mechanism — running chunks,
scattering pages, sampling the first token — lives in
``serving.engine.ServingEngine`` (``chunked_prefill=True``). Budget math and
tick anatomy are documented in docs/scheduler.md.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


@dataclass
class PrefillTask:
    """One request mid-prefill: admitted to a slot, pages allocated up to
    the next chunk, ``pos`` .. ``total`` still to run. ``n_skip`` prompt
    positions were served from the prefix cache and are never recomputed."""
    req: Any                    # serving.engine.Request
    slot: int
    total: int                  # n_prefix + len(prompt) positions
    n_skip: int = 0             # positions skipped via prefix-cache hit
    pos: int = 0                # next position to prefill (starts at n_skip)
    seq: int = 0                # admission order (FCFS tiebreak)
    embeds: Any = None          # [1, total, d] prompt embeddings (engine)
    cache1: Any = None          # dense engines: batch-1 prefill cache
    prefix_keys: Any = None     # paged engines: prefix-closed page digests
    t_start: float = 0.0        # prefill start (queue_s boundary)
    stalled: bool = False       # pool pressure on last attempt; cleared by
    #                             the next successful chunk. Stalled tasks
    #                             are planned last (healthy work first) and
    #                             are the only admission-side eviction
    #                             victims — a stalled task is by definition
    #                             queued-behind, while decoders and
    #                             progressing tasks free pages by finishing

    @property
    def remaining(self) -> int:
        return self.total - self.pos


@dataclass
class ChunkPlan:
    """One prefill-chunk dispatch: ``n_tok`` valid tokens of ``task``'s
    prompt starting at position ``start`` (padded to the engine's static
    chunk shape)."""
    task: PrefillTask
    start: int
    n_tok: int


@dataclass
class TickPlan:
    """What one engine tick executes: prefill chunks, then up to
    ``decode_steps`` fused decode steps for the active slots."""
    chunks: List[ChunkPlan] = field(default_factory=list)
    decode_steps: int = 0
    budget_used: int = 0


class ChunkedScheduler:
    """Token-budget continuous-batching policy.

    Budget math per tick (``plan_tick``):

    1. **Decode first.** ``n_active`` decoding slots reserve
       ``n_active * decode_steps`` tokens, with
       ``decode_steps = clamp(token_budget // n_active, 1, tick_tokens)``.
       Active decoders always advance at least one step — prefill pressure
       can slow decode to one token per tick but never stall it — and when
       the budget is generous they keep the engine's full fused-tick depth.
    2. **Chunks fill the remainder.** In-flight prefills (FCFS by admission
       order) take chunks of ``min(chunk_size, remaining prompt, remaining
       budget)`` valid tokens until the budget is spent. A task may receive
       several chunks in one tick on an idle engine; with zero leftover
       budget it simply waits (decoders free budget when they finish).
    3. **Progress floor.** With no active decoders the whole budget (>= 1
       token, enforced at construction) goes to prefill, so the head task
       always gets a chunk — even ``token_budget < chunk_size`` degrades to
       slow prefill, not deadlock.

    The scheduler owns the waiting queue and the in-flight task table; the
    engine owns slots, pools, and device state. ``stalled`` tasks (pool
    pressure on their last attempt) are planned after healthy tasks and
    retried every tick until pages free up or they are evicted.

    Invariants the engine relies on:

    - ``tasks`` is keyed by slot and a slot holds at most one in-flight
      prefill (asserted in ``start_task``); a slot is *either* decoding
      or mid-prefill, never both.
    - ``seq`` is monotone in admission order, so the FCFS tiebreak in
      ``plan_tick`` is stable across ticks — a task's chunk priority
      never changes while it is in flight.
    - ``waiting`` preserves arrival order except for ``front=True``
      re-queues (preemption victims and admission-capacity deferrals keep
      their seniority).
    - ``plan_tick`` only *reads* scheduler state: planning a tick and
      then not executing it (or executing it partially under pool
      pressure) leaves nothing to roll back here — ``task.pos`` advances
      only when the engine reports the chunk ran.
    """

    def __init__(self, chunk_size: int, token_budget: int):
        if chunk_size < 1:
            raise ValueError(f"chunk_size must be >= 1, got {chunk_size}")
        if token_budget < 1:
            raise ValueError(f"token_budget must be >= 1, "
                             f"got {token_budget}")
        self.chunk_size = chunk_size
        self.token_budget = token_budget
        self.waiting: List[Any] = []            # Requests not yet admitted
        self.tasks: Dict[int, PrefillTask] = {}  # slot -> in-flight prefill
        self._seq = 0

    # -- queue / task lifecycle -------------------------------------------
    def submit(self, req, front: bool = False):
        """Queue a request for admission. ``front=True`` restores
        seniority (preempted / capacity-deferred requests re-enter at the
        head so they cannot be starved by a steady arrival stream)."""
        if front:
            self.waiting.insert(0, req)
        else:
            self.waiting.append(req)

    @property
    def pending(self) -> int:
        """Requests this scheduler still owes work: waiting + mid-prefill.
        (Decoding slots are the engine's; the engine's own ``pending``
        adds them.)"""
        return len(self.waiting) + len(self.tasks)

    def start_task(self, task: PrefillTask) -> PrefillTask:
        """Admit a request into a slot: it now competes for chunk budget."""
        assert task.slot not in self.tasks, f"slot {task.slot} mid-prefill"
        task.seq = self._seq
        task.pos = task.n_skip
        self._seq += 1
        self.tasks[task.slot] = task
        return task

    def finish_task(self, slot: int) -> PrefillTask:
        """Prefill complete (or request finished at prefill): drop the
        task; the engine flips the slot to decoding."""
        return self.tasks.pop(slot)

    def requeue_task(self, slot: int) -> Optional[PrefillTask]:
        """Preemption: the slot's in-flight prefill is abandoned and its
        request goes back to the *front* of the waiting queue (it has
        seniority). Written chunks are discarded — on re-admission the
        prefix cache may still serve the pages the first attempt
        registered, so the retry can be cheaper than the original."""
        task = self.tasks.pop(slot, None)
        if task is not None:
            self.submit(task.req, front=True)
        return task

    # -- the per-tick policy ----------------------------------------------
    def plan_tick(self, n_active: int, tick_tokens: int) -> TickPlan:
        """Pack one tick: decode reservation first, then prefill chunks
        FCFS under what is left of ``token_budget``.

        The budget bounds *planned* work. A prefill that completes during
        this tick's chunk stage joins the same tick's decode stage (the
        engine re-reads the active set), adding up to ``decode_steps``
        unplanned decode tokens — deliberate: delaying that slot one tick
        would cost first-token latency to enforce an accounting nicety.

        ``decode_steps`` is denominated in *emitted tokens per slot*, not
        engine-loop iterations — the contract that keeps this policy
        mechanism-agnostic. The plain fused tick emits one token per loop
        step, so the two readings coincide; the speculative tick
        (``spec_decode=True``) emits a variable 1..spec_k accepted tokens
        per verify pass and clamps its emit count to this same cap, so a
        tick's decode stage never exceeds ``n_active * decode_steps``
        tokens regardless of how few HBM passes produced them."""
        plan = TickPlan()
        if n_active:
            plan.decode_steps = max(
                1, min(tick_tokens, self.token_budget // n_active))
        left = self.token_budget - n_active * plan.decode_steps
        # stalled tasks go last: healthy work first, but they still retry
        # every tick (their stall may clear the moment a decoder finishes)
        for task in sorted(self.tasks.values(),
                           key=lambda t: (t.stalled, t.seq)):
            pos = task.pos
            while left > 0 and pos < task.total:
                n = min(self.chunk_size, task.total - pos, left)
                plan.chunks.append(ChunkPlan(task, pos, n))
                pos += n
                left -= n
        plan.budget_used = (n_active * plan.decode_steps
                            + sum(c.n_tok for c in plan.chunks))
        return plan
