"""Asyncio serving front-end: the production rim over one or more engines.

Everything below this module is a synchronous tick machine
(``ServingEngine.step_fused`` advances every live request by up to one
token-budget's worth of work); everything above it is a robot fleet —
thousands of clients that arrive at their own times, stream tokens as they
are produced, hang up mid-generation, and must be told to back off when the
system is full. ``AsyncFrontend`` is the adapter between the two:

- **Streaming.** ``submit()`` returns a :class:`TokenStream` — an async
  iterator that yields tokens as the owning replica's ticks produce them.
  The first yielded token is the client-observed TTFT boundary
  (``FrontendStats.ttft_s``), which includes front-end queueing the
  engine-side ``EngineStats.ttft_s`` cannot see.
- **Cancellation.** ``TokenStream.cancel()`` (or ``AsyncFrontend.cancel``)
  aborts a request wherever it is — staged, queued, mid-prefill, or
  mid-decode. The engine-side hook (``ServingEngine.cancel``) frees the
  slot and its pool pages, so a robot that hung up stops holding KV
  capacity within one tick.
- **Backpressure.** Admission is bounded per replica (``queue_limit``
  requests staged + pending). When every routable replica is at its limit,
  ``submit`` raises :class:`Backpressure` carrying a ``retry_after_s``
  estimate (depth x the replica's EWMA tick time) instead of queueing
  unboundedly — the reject-with-retry-after contract load balancers expect.
- **Prefix-cache-aware routing.** The content-addressed page digests the
  KV pool already shares pages under (``engine.prefix_page_keys``) double
  as the routing key: a repeat observation is routed to the replica whose
  pool holds the longest run of its prefix pages (``KVPool.match_prefix``),
  falling back to least-loaded. A robot's control loop therefore sticks to
  the replica that has its camera-frame + instruction KV, and the prefix
  cache keeps paying off across replicas instead of being diluted by
  round-robin.

Concurrency model — everything engine-flavoured happens at tick
boundaries, on one driver coroutine per replica::

      submit()/cancel() (event loop)          driver i (coroutine)
      ───────────────────────────────         ─────────────────────────
      stage request -> _staged[i]   ──────►   drain staged + cancels
      stage uid     -> _cancels[i]            eng.submit / eng.cancel
      set _wake[i]                            tick: eng.step_fused()
                                                (in a worker thread, so
                                                 replicas tick in parallel
                                                 and the loop stays live)
      async for tok in stream  ◄──────────    pump: push new out_tokens
                                              per live stream, close
                                              finished ones

    The engine is only ever touched between its own ticks by its own
    driver, so no engine state needs locking; the staging deques and the
    per-stream asyncio queues are the only cross-context structures.

No HTTP here on purpose: the bench and the launch driver speak to this
class directly, and a transport (FastAPI/grpc) would wrap ``submit`` /
``TokenStream`` 1:1 without touching the scheduling semantics. See
docs/serving.md for the operations guide.
"""
from __future__ import annotations

import asyncio
import time
from collections import deque
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional, Sequence

import numpy as np

from repro.serving.engine import Request, ServingEngine, prefix_page_keys
from repro.serving.scheduler import BEST_EFFORT, REALTIME


class Backpressure(RuntimeError):
    """Every replica routable *for this request's class* is at its
    admission limit.

    Carries ``retry_after_s`` — the least-loaded replica's queue depth x
    its EWMA tick wall time (the engine's own measurement once it has
    ticked, the front-end's driver-side estimate before that), i.e. a
    first-order estimate of when a slot's worth of queue will have
    drained. Clients (and the workload replayer) are expected to back off
    for that long and resubmit. ``priority`` echoes the rejected class:
    with a ``realtime_reserve`` configured, best-effort traffic hits its
    (lower) limit first, so a flood of best-effort rejects while realtime
    still admits is the system working as designed."""

    def __init__(self, retry_after_s: float, depth: int, limit: int,
                 priority: str = BEST_EFFORT):
        super().__init__(
            f"admission queues full (depth {depth} >= limit {limit} for "
            f"{priority} on every replica); retry after "
            f"{retry_after_s:.3f}s")
        self.retry_after_s = retry_after_s
        self.depth = depth
        self.limit = limit
        self.priority = priority


_DONE = object()        # stream sentinel: request finished or was cancelled


class TokenStream:
    """Handle for one in-flight request: async-iterate it for tokens.

    ``async for tok in stream`` yields ints as the replica produces them
    and ends when the request finishes or is cancelled; ``await
    stream.tokens()`` collects the remainder. ``cancelled`` distinguishes
    a cancel-truncated stream from a naturally finished one. The underlying
    engine :class:`Request` is exposed as ``.request`` (its ``out_tokens``
    is the authoritative full list, identical to what the stream yielded)."""

    def __init__(self, uid: int, req: Request, replica: int):
        self.uid = uid
        self.request = req
        self.replica = replica
        self.cancelled = False
        self.t_submit = time.perf_counter()
        self.t_first: Optional[float] = None       # first streamed token
        self.t_done: Optional[float] = None
        self._chan: asyncio.Queue = asyncio.Queue()
        self._sent = 0                             # tokens pumped so far
        self._closed = False
        self._error: Optional[BaseException] = None

    def __aiter__(self):
        return self

    async def __anext__(self) -> int:
        item = await self._chan.get()
        if item is _DONE:
            if self._error is not None:
                raise self._error
            raise StopAsyncIteration
        return item

    async def tokens(self) -> List[int]:
        """Drain the stream: every remaining token, in order."""
        return [tok async for tok in self]

    def cancel(self):
        """Stage a cancellation with the owning front-end (set by submit)."""
        self._frontend.cancel(self)

    # internal: wired by AsyncFrontend.submit
    _frontend: "AsyncFrontend" = None


@dataclass
class FrontendStats:
    """Fleet-facing counters, aggregated across replicas.

    ``ttft_s`` / ``latency_s`` are client-observed (submit wall time ->
    first streamed token / stream close), so they include front-end
    queueing and routing — the numbers an SLO is written against, unlike
    the engine-internal ``EngineStats`` boundaries."""
    submitted: int = 0
    completed: int = 0
    cancelled: int = 0
    rejected: int = 0           # Backpressure raises
    routed_prefix: int = 0      # routed by prefix-cache affinity
    routed_load: int = 0        # least-loaded fallback
    ttft_s: List[float] = field(default_factory=list)
    latency_s: List[float] = field(default_factory=list)

    def report(self) -> Dict[str, float]:
        rep = {"submitted": self.submitted, "completed": self.completed,
               "cancelled": self.cancelled, "rejected": self.rejected,
               "routed_prefix": self.routed_prefix,
               "routed_load": self.routed_load}
        if self.ttft_s:
            rep["ttft_p50_s"] = float(np.percentile(self.ttft_s, 50))
            rep["ttft_p99_s"] = float(np.percentile(self.ttft_s, 99))
        if self.latency_s:
            rep["latency_p50_s"] = float(np.percentile(self.latency_s, 50))
            rep["latency_p99_s"] = float(np.percentile(self.latency_s, 99))
        return rep


class AsyncFrontend:
    """Asyncio front-end over ``engines`` (homogeneous or not).

    Parameters
    ----------
    engines: the replica set. Each must be exclusively owned by this
        front-end (its queue/slots are mutated from the driver).
    queue_limit: per-replica admission bound — staged + engine-pending
        requests. ``submit`` raises :class:`Backpressure` when every
        replica is at the limit.
    offload_ticks: run each replica's ticks in a worker thread (default),
        so replicas tick in parallel and the event loop stays responsive
        during a tick. ``False`` ticks inline on the loop — fully
        single-threaded and deterministic, the mode the bit-equality bench
        uses.
    realtime_reserve: admission slots per replica held back for the
        ``realtime`` class: best-effort requests admit against
        ``queue_limit - realtime_reserve`` while realtime admits against
        the full ``queue_limit``, so a flood of best-effort traffic can
        fill its share and start bouncing without ever crowding a control
        loop out of admission. 0 (default) disables the split — both
        classes see one limit, the pre-priority behavior.

    Use as an async context manager (``async with AsyncFrontend(...)``),
    or call ``start()`` / ``stop()`` explicitly. ``stop()`` cancels the
    drivers without draining; call ``drain()`` first to wait for in-flight
    work."""

    def __init__(self, engines: Sequence[ServingEngine],
                 queue_limit: int = 64, offload_ticks: bool = True,
                 realtime_reserve: int = 0):
        if not engines:
            raise ValueError("AsyncFrontend needs at least one engine")
        if queue_limit < 1:
            raise ValueError(f"queue_limit must be >= 1, got {queue_limit}")
        if not 0 <= realtime_reserve < queue_limit:
            raise ValueError(
                f"realtime_reserve must be in [0, queue_limit), got "
                f"{realtime_reserve} with queue_limit {queue_limit}")
        self.engines = list(engines)
        self.queue_limit = queue_limit
        self.realtime_reserve = realtime_reserve
        self.offload_ticks = offload_ticks
        self.stats = FrontendStats()
        n = len(self.engines)
        self._staged: List[Deque[TokenStream]] = [deque() for _ in range(n)]
        self._cancels: List[set] = [set() for _ in range(n)]
        self._live: List[Dict[int, TokenStream]] = [{} for _ in range(n)]
        self._wake: List[asyncio.Event] = []
        self._tick_ewma = [1e-3] * n        # per-replica tick wall estimate
        self._uid = 0
        self._running = False
        self._tasks: List[asyncio.Task] = []
        self._pool: Optional[ThreadPoolExecutor] = None

    # -- lifecycle ---------------------------------------------------------
    async def start(self):
        if self._running:
            return
        self._running = True
        self._wake = [asyncio.Event() for _ in self.engines]
        if self.offload_ticks:
            self._pool = ThreadPoolExecutor(
                max_workers=len(self.engines),
                thread_name_prefix="engine-tick")
        self._tasks = [asyncio.ensure_future(self._drive(i))
                       for i in range(len(self.engines))]

    async def stop(self):
        """Stop the drivers. In-flight streams are closed (their consumers
        see end-of-stream); un-drained requests stay in the engines."""
        if not self._running:
            return
        self._running = False
        for ev in self._wake:
            ev.set()
        results = await asyncio.gather(*self._tasks, return_exceptions=True)
        self._tasks = []
        if self._pool is not None:
            self._pool.shutdown(wait=True)
            self._pool = None
        for live in self._live:
            for stream in live.values():
                stream._chan.put_nowait(_DONE)
            live.clear()
        for r in results:
            if isinstance(r, BaseException) \
                    and not isinstance(r, asyncio.CancelledError):
                raise r

    async def __aenter__(self):
        await self.start()
        return self

    async def __aexit__(self, *exc):
        await self.stop()

    async def drain(self, poll_s: float = 1e-3):
        """Wait until every accepted request has finished or been
        cancelled (staged queues empty, no live streams)."""
        while any(self._staged) or any(self._live) or any(self._cancels):
            await asyncio.sleep(poll_s)

    # -- observability -----------------------------------------------------
    def stats_snapshot(self) -> Dict[str, float]:
        """One flat, JSON-serializable dict of fleet health — the payload an
        autoscaler or metrics scraper polls between ticks.

        Keys: every :meth:`FrontendStats.report` entry under a
        ``frontend_`` prefix, ``replicas``, and per-replica gauges
        ``replica{i}_depth`` (staged + engine-pending), ``replica{i}_pending``
        (engine-side only), ``replica{i}_tick_ewma_s`` (EWMA tick wall time —
        engine-measured once it has ticked; with depth, the retry-after
        estimate Backpressure quotes), and ``replica{i}_tokens_decoded``;
        speculative replicas additionally report
        ``replica{i}_spec_accept_per_pass``, and replicas that scored any
        deadlined request report the per-class SLO scoreboard
        (``replica{i}_deadline_attainment_realtime`` / ``_best_effort``
        and ``replica{i}_preemptions_*`` counters); paged replicas report
        cache gauges (``replica{i}_pages_in_use`` / ``_pages_hwm`` /
        ``_cache_bytes_hwm``), and mesh-sharded replicas additionally their
        axis sizes (``replica{i}_mesh_model``) and *per-device* figures
        (``replica{i}_cache_bytes_hwm_shard`` / ``_pages_in_use_shard``) —
        the summed ``cache_bytes_hwm`` is not a per-device number once the
        pool is partitioned, and a scraper sizing HBM must read the shard
        keys. All values are floats, the snapshot is safe to take before
        ``start()`` (gauges read zero), and nothing here blocks on a
        tick."""
        snap: Dict[str, float] = {}
        for k, v in self.stats.report().items():
            snap[f"frontend_{k}"] = float(v)
        snap["replicas"] = float(len(self.engines))
        for i, eng in enumerate(self.engines):
            snap[f"replica{i}_depth"] = float(self.depth(i))
            snap[f"replica{i}_pending"] = float(eng.pending)
            snap[f"replica{i}_tick_ewma_s"] = float(self.tick_ewma(i))
            snap[f"replica{i}_tokens_decoded"] = float(
                eng.stats.tokens_decoded)
            ph = eng.stats.phase_report()
            for k, v in ph.items():
                if k.startswith(("deadline_attainment_", "deadline_total_",
                                 "preemptions_", "pages_", "cache_bytes_",
                                 "mesh_")) \
                        or k == "spec_accept_per_pass":
                    snap[f"replica{i}_{k}"] = float(v)
        return snap

    # -- admission ---------------------------------------------------------
    def depth(self, i: int) -> int:
        """Replica ``i``'s admission depth: staged + engine-pending."""
        return len(self._staged[i]) + self.engines[i].pending

    def class_limit(self, priority: str) -> int:
        """Admission limit the class admits against: realtime sees the
        full ``queue_limit``, best-effort yields ``realtime_reserve``
        slots of it."""
        if priority == REALTIME:
            return self.queue_limit
        return self.queue_limit - self.realtime_reserve

    def tick_ewma(self, i: int) -> float:
        """Replica ``i``'s per-tick wall-time estimate: the engine's own
        EWMA once it has ticked (it sees every tick, including those
        driven outside this front-end), the driver-side estimate before
        that."""
        eng_ewma = self.engines[i].stats.tick_ewma_s
        return eng_ewma if eng_ewma > 0 else self._tick_ewma[i]

    def _route(self, prompt: np.ndarray, patches: Optional[np.ndarray],
               priority: str = BEST_EFFORT) -> int:
        """Pick a replica: longest prefix-page match first, least-loaded
        fallback. Raises :class:`Backpressure` when everything is full.

        The digest is computed per distinct (model, page_size, kv_dtype)
        signature — identical replicas share one computation — and matched
        against each pool's live prefix cache. A match only wins while the
        replica is under the class's admission limit
        (``class_limit(priority)``): affinity never overrides admission
        control (a full replica's cache hit is worth less than another
        replica's free slot, because the hit only skips prefill while the
        queue costs whole requests)."""
        limit = self.class_limit(priority)
        keys_cache: Dict[tuple, List[bytes]] = {}
        best, best_hits = -1, 0
        for i, eng in enumerate(self.engines):
            if eng.pool is None or not eng.prefix_cache:
                continue
            if self.depth(i) >= limit:
                continue
            n_prefix = (eng.cfg.vision.num_tokens
                        if patches is not None and eng.cfg.vision is not None
                        else 0)
            sig = (eng.cfg.name, eng.page_size, eng.kv_dtype, n_prefix)
            if sig not in keys_cache:
                keys_cache[sig] = prefix_page_keys(
                    eng.cfg.name, eng.page_size, eng.kv_dtype, prompt,
                    patches, n_prefix)
            hits = eng.pool.match_prefix(keys_cache[sig])
            if hits > best_hits:
                best, best_hits = i, hits
        if best >= 0:
            self.stats.routed_prefix += 1
            return best
        cands = [i for i in range(len(self.engines))
                 if self.depth(i) < limit]
        if not cands:
            i = min(range(len(self.engines)), key=self.depth)
            retry = max(1e-3, self.depth(i) * self.tick_ewma(i))
            self.stats.rejected += 1
            raise Backpressure(retry, self.depth(i), limit, priority)
        self.stats.routed_load += 1
        return min(cands, key=self.depth)

    async def submit(self, prompt: np.ndarray, max_tokens: int,
                     patches: Optional[np.ndarray] = None,
                     priority: str = BEST_EFFORT,
                     deadline_s: float = 0.0) -> TokenStream:
        """Admit one request: route it, stage it with the chosen replica's
        driver, and return its :class:`TokenStream`. Raises
        :class:`Backpressure` instead of queueing past the class's
        admission limit. ``priority``/``deadline_s`` ride the engine
        :class:`Request` into the scheduler: realtime requests admit
        against the full ``queue_limit``, jump the replica's waiting
        queue (EDF within class), and have their deadline defended by the
        engine's SLO controller when it runs one (``slo_hz > 0``)."""
        if not self._running:
            raise RuntimeError("AsyncFrontend not started")
        i = self._route(prompt, patches, priority)
        uid, self._uid = self._uid, self._uid + 1
        req = Request(uid=uid, prompt=np.asarray(prompt, np.int32),
                      max_tokens=max_tokens, patches=patches,
                      priority=priority, deadline_s=deadline_s)
        stream = TokenStream(uid, req, i)
        stream._frontend = self
        self._staged[i].append(stream)
        self.stats.submitted += 1
        self._wake[i].set()
        return stream

    def cancel(self, stream: TokenStream):
        """Stage a cancellation for ``stream``; the owning driver frees the
        slot/pages at the next tick boundary and closes the stream. Safe on
        an already-finished stream (no-op)."""
        if stream._closed:
            return
        self._cancels[stream.replica].add(stream.uid)
        self._wake[stream.replica].set()

    # -- the per-replica driver --------------------------------------------
    def _drain_control(self, i: int):
        """Move staged submissions and cancellations into engine ``i``.
        Runs on the event loop between ticks — the only place besides the
        tick itself that mutates the engine."""
        eng = self.engines[i]
        while self._staged[i]:
            stream = self._staged[i].popleft()
            if stream.uid in self._cancels[i]:
                # cancelled before it ever reached the engine
                self._cancels[i].discard(stream.uid)
                self._close(i, stream, cancelled=True)
                continue
            eng.submit(stream.request)
            self._live[i][stream.uid] = stream
        for uid in sorted(self._cancels[i]):
            self._cancels[i].discard(uid)
            stream = self._live[i].pop(uid, None)
            if stream is None:
                continue        # finished before the cancel drained
            eng.cancel(uid)
            self._close(i, stream, cancelled=True)

    def _close(self, i: int, stream: TokenStream, cancelled: bool):
        now = time.perf_counter()
        stream.t_done = now
        stream.cancelled = cancelled
        stream._closed = True
        if cancelled:
            self.stats.cancelled += 1
        else:
            self.stats.completed += 1
            self.stats.latency_s.append(now - stream.t_submit)
        stream._chan.put_nowait(_DONE)

    def _pump(self, i: int):
        """Push tokens the last tick produced into their streams; close
        streams whose requests finished."""
        now = time.perf_counter()
        done_uids = []
        for uid, stream in self._live[i].items():
            toks = stream.request.out_tokens
            if stream._sent < len(toks):
                if stream.t_first is None:
                    stream.t_first = now
                    self.stats.ttft_s.append(now - stream.t_submit)
                for tok in toks[stream._sent:]:
                    stream._chan.put_nowait(tok)
                stream._sent = len(toks)
            if stream.request.done:
                done_uids.append(uid)
        for uid in done_uids:
            self._close(i, self._live[i].pop(uid), cancelled=False)

    async def _drive(self, i: int):
        """Replica ``i``'s tick loop: drain control -> tick -> pump, or
        park on the wake event when there is nothing to do."""
        eng = self.engines[i]
        loop = asyncio.get_event_loop()
        while self._running:
            self._drain_control(i)
            if not eng.pending:
                if not self._staged[i] and not self._cancels[i]:
                    self._wake[i].clear()
                    # re-check after clear: a submit between the test and
                    # the clear must not be lost (set-then-clear race)
                    if not self._staged[i] and not self._cancels[i] \
                            and self._running:
                        await self._wake[i].wait()
                continue
            t0 = time.perf_counter()
            if self.offload_ticks:
                await loop.run_in_executor(self._pool, eng.step_fused)
            else:
                eng.step_fused()
                await asyncio.sleep(0)      # let submit/cancel interleave
            self._tick_ewma[i] = (0.8 * self._tick_ewma[i]
                                  + 0.2 * (time.perf_counter() - t0))
            self._pump(i)
