from repro.serving.engine import EngineStats, Request, ServingEngine
from repro.serving.kv_pool import KVPool, PoolExhausted
from repro.serving.sampler import greedy, sample, sample_token

__all__ = ["EngineStats", "KVPool", "PoolExhausted", "Request",
           "ServingEngine", "greedy", "sample", "sample_token"]
