from repro.serving.engine import EngineStats, Request, ServingEngine
from repro.serving.kv_pool import KVPool, PoolExhausted
from repro.serving.sampler import greedy, sample, sample_token
from repro.serving.scheduler import (ChunkedScheduler, ChunkPlan,
                                     PrefillTask, TickPlan)

__all__ = ["ChunkedScheduler", "ChunkPlan", "EngineStats", "KVPool",
           "PoolExhausted", "PrefillTask", "Request", "ServingEngine",
           "TickPlan", "greedy", "sample", "sample_token"]
