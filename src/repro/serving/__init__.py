from repro.serving.engine import (EngineStats, Request, ServingEngine,
                                  prefix_page_keys)
from repro.serving.frontend import (AsyncFrontend, Backpressure,
                                    FrontendStats, TokenStream)
from repro.serving.kv_pool import KVPool, PoolExhausted
from repro.serving.sampler import greedy, sample, sample_token
from repro.serving.scheduler import (ChunkedScheduler, ChunkPlan,
                                     PrefillTask, TickPlan)

__all__ = ["AsyncFrontend", "Backpressure", "ChunkedScheduler", "ChunkPlan",
           "EngineStats", "FrontendStats", "KVPool", "PoolExhausted",
           "PrefillTask", "Request", "ServingEngine", "TickPlan",
           "TokenStream", "greedy", "prefix_page_keys", "sample",
           "sample_token"]
