from repro.serving.engine import EngineStats, Request, ServingEngine
from repro.serving.sampler import greedy, sample, sample_token

__all__ = ["EngineStats", "Request", "ServingEngine", "greedy", "sample",
           "sample_token"]
