from repro.serving.engine import Request, ServingEngine
from repro.serving.sampler import greedy, sample

__all__ = ["Request", "ServingEngine", "greedy", "sample"]
