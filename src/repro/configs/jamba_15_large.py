"""jamba-1.5-large-398b [hybrid] — 72L d_model=8192 64H (GQA kv=8)
d_ff=24576 vocab=65536, MoE 16 experts top-2 on every other layer,
Mamba:attention 7:1 interleave. [arXiv:2403.19887]

Adaptation note (DESIGN.md §10): the state mixer is our Mamba2/SSD block
(state=128) rather than Jamba's Mamba-1 — the SSD formulation is what our
Pallas kernel targets and is the TPU-idiomatic choice.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    num_layers=72,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=24_576,
    vocab_size=65_536,
    head_dim=128,
    num_experts=16,
    top_k=2,
    moe_d_ff=24_576,
    moe_every=2,            # MoE on odd layers, dense MLP on even
    attn_every=8,           # 1 attention layer per 8 (7 mamba : 1 attn)
    ssm_state=128,
)
