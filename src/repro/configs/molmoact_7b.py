"""molmoact-7b — the paper's own workload (MolmoAct-7B, arXiv:2508.07917).

Qwen2-7B reasoning backbone + ViT-L/14 vision tower (frontend stubbed as
patch embeddings) + discrete action-token head. Phase lengths follow the
MolmoAct action-reasoning recipe: prompt + depth/trace CoT tokens, then
action tokens per control step.
"""
from repro.configs.base import ActionConfig, ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="molmoact-7b",
    family="vlm",
    num_layers=28,
    d_model=3584,
    num_heads=28,
    num_kv_heads=4,
    d_ff=18_944,
    vocab_size=152_064,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    vision=VisionConfig(num_layers=24, d_model=1024, num_heads=16, d_ff=4096,
                        num_tokens=576, embed_dim=1024),
    action=ActionConfig(mode="discrete", num_action_tokens=48),
    n_prompt_tokens=64,
    n_cot_tokens=144,       # depth tokens + visual trace ("reason in space")
)

# Continuous-action variant with a DiT head (paper §2: "specialized decoders
# such as Diffusion Transformers (DiT)").
import dataclasses as _dc

CONFIG_DIT = _dc.replace(
    CONFIG,
    name="molmoact-7b-dit",
    action=ActionConfig(mode="dit", dit_layers=6, dit_d_model=512,
                        dit_heads=8, dit_steps=10, action_dim=7, horizon=8),
)
