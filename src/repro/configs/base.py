"""Config dataclasses for all architectures and input shapes.

Every assigned architecture is expressed as a single ``ModelConfig``; the
model code is driven entirely by these fields (no per-arch model classes).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple

GLOBAL_WINDOW = 0  # sentinel: "no sliding window" (full causal attention)


@dataclass(frozen=True)
class VisionConfig:
    """Vision/audio encoder tower. The modality frontend (conv/patchify) is a
    stub: ``input_specs()`` provides precomputed frame/patch embeddings with
    ``embed_dim`` features; the transformer tower here is real."""
    num_layers: int
    d_model: int
    num_heads: int
    d_ff: int
    num_tokens: int          # frames (audio) or patches (image)
    embed_dim: int           # dim of the stubbed frontend embeddings
    use_layernorm: bool = True


@dataclass(frozen=True)
class ActionConfig:
    """Action generation head (the paper's bottleneck phase).

    mode='discrete': actions are tokens in the LM vocab (MolmoAct-style).
    mode='dit':      a small Diffusion Transformer decodes continuous
                     trajectories conditioned on LM hidden states.
    """
    mode: str = "discrete"            # 'discrete' | 'dit'
    num_action_tokens: int = 24       # tokens decoded per control step
    # DiT head (only used when mode == 'dit')
    dit_layers: int = 6
    dit_d_model: int = 512
    dit_heads: int = 8
    dit_steps: int = 10               # diffusion denoising iterations
    action_dim: int = 7               # e.g. 7-DoF end effector
    horizon: int = 8                  # trajectory length


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                 # 0 -> d_model // num_heads
    qkv_bias: bool = False
    norm: str = "rmsnorm"             # rmsnorm | layernorm
    pos: str = "rope"                 # rope | absolute
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    act: str = "silu"                 # silu (gated) | gelu (gated) | gelu_plain
    tie_embeddings: bool = False

    # --- attention pattern ---
    # window length per layer position modulo len(window_pattern);
    # GLOBAL_WINDOW means full causal. gemma3: (W,W,W,W,W,0) = 5 local : 1 global.
    window_pattern: Tuple[int, ...] = (GLOBAL_WINDOW,)
    # --- MoE ---
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    moe_every: int = 1                # MoE on layers where i % moe_every == moe_every-1
    dense_residual: bool = False      # arctic: dense MLP in parallel with MoE
    # §Perf: pad the expert dim so it divides the TP axis (e.g. granite-moe's
    # 40 -> 48 over model=16). Padded experts are masked out of routing
    # (router logits = -inf) and carry zero tokens; param_counts() reports
    # the real expert count.
    num_experts_padded: int = 0
    # --- SSM / hybrid ---
    ssm_state: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_conv: int = 4
    attn_every: int = 0               # hybrid: attention on layers i % attn_every == attn_every//2
    # --- encoder-decoder ---
    encoder: Optional[VisionConfig] = None   # whisper audio tower (cross-attn)
    # --- VLM ---
    vision: Optional[VisionConfig] = None    # prefix-token vision tower
    # --- VLA ---
    action: Optional[ActionConfig] = None
    # VLA phase lengths for the XPU simulator (CoT reasoning etc.)
    n_prompt_tokens: int = 64
    n_cot_tokens: int = 128

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(self.num_heads, 1))
        assert self.family in ("dense", "moe", "ssm", "hybrid", "encdec", "vlm")
        if self.num_heads:
            assert self.num_heads % max(self.num_kv_heads, 1) == 0, self.name

    # --- per-layer pattern helpers -------------------------------------
    def layer_window(self, i: int) -> int:
        return self.window_pattern[i % len(self.window_pattern)]

    def windows(self) -> Tuple[int, ...]:
        return tuple(self.layer_window(i) for i in range(self.num_layers))

    def is_attn_layer(self, i: int) -> bool:
        if self.family == "ssm":
            return False
        if self.attn_every:
            return i % self.attn_every == self.attn_every // 2
        return True

    def is_moe_layer(self, i: int) -> bool:
        if not self.num_experts:
            return False
        return i % self.moe_every == self.moe_every - 1

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k: SSM/hybrid or mostly-sliding-window."""
        if self.family in ("ssm", "hybrid"):
            return True
        return any(w != GLOBAL_WINDOW for w in self.window_pattern)

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs decode (whisper is enc-dec)

    # --- parameter counting (analytical; used by sim + roofline) -------
    def param_counts(self) -> dict:
        """Analytical parameter counts, split by component."""
        d, hd = self.d_model, self.head_dim
        counts = {"embed": self.vocab_size * d, "lm_head": 0 if self.tie_embeddings else self.vocab_size * d}
        attn = mlp = moe = ssm = 0.0
        for i in range(self.num_layers):
            if self.is_attn_layer(i):
                q = d * self.num_heads * hd
                kv = 2 * d * self.num_kv_heads * hd
                o = self.num_heads * hd * d
                attn += q + kv + o
            elif self.family in ("ssm", "hybrid"):
                d_in = self.ssm_expand * d
                nheads = d_in // self.ssm_head_dim
                ssm += d * (2 * d_in + 2 * self.ssm_state + nheads) + d_in * d
            if self.is_moe_layer(i):
                moe += self.num_experts * 3 * d * self.moe_d_ff + d * self.num_experts
                if self.dense_residual and self.d_ff:
                    mlp += 3 * d * self.d_ff
            elif self.d_ff and self.family != "ssm":
                gate = 3 if self.act in ("silu", "gelu") else 2
                mlp += gate * d * self.d_ff
        tower = 0.0
        for enc in (self.encoder, self.vision):
            if enc is not None:
                # MHA (4 d^2) + plain-gelu MLP (2 d d_ff) per layer + projector
                tower += enc.num_layers * (4 * enc.d_model ** 2 + 2 * enc.d_model * enc.d_ff)
                tower += enc.embed_dim * enc.d_model + enc.d_model * d
        counts.update(attn=attn, mlp=mlp, moe=moe, ssm=ssm, tower=tower)
        counts["total"] = sum(counts.values())
        # active params per token (MoE: only top_k experts fire)
        active = counts["total"] - moe
        for i in range(self.num_layers):
            if self.is_moe_layer(i):
                active += self.top_k * 3 * d * self.moe_d_ff + d * self.num_experts
        counts["active"] = active
        return counts

    # --- reduced config for CPU smoke tests ----------------------------
    def reduced(self) -> "ModelConfig":
        """Same family/topology, tiny dimensions. Runs a real fwd/train step
        on CPU in well under a second."""
        kv = max(1, min(self.num_kv_heads, 2))
        heads = kv * max(1, (self.num_heads // max(self.num_kv_heads, 1)))
        heads = min(heads, 4)
        heads = max(kv, (heads // kv) * kv)
        updates = dict(
            name=self.name + "-reduced",
            num_layers=min(self.num_layers, 4) if self.attn_every == 0 else min(self.num_layers, 2 * max(self.attn_every, 1)),
            d_model=64, num_heads=heads, num_kv_heads=kv, head_dim=16,
            d_ff=96 if self.d_ff else 0, vocab_size=256,
            num_experts=min(self.num_experts, 4), top_k=min(self.top_k, 2),
            moe_d_ff=48 if self.num_experts else 0,
            ssm_state=min(self.ssm_state, 16), ssm_head_dim=16,
            window_pattern=tuple(min(w, 32) if w != GLOBAL_WINDOW else w
                                 for w in self.window_pattern),
        )
        if self.encoder:
            updates["encoder"] = dataclasses.replace(
                self.encoder, num_layers=2, d_model=64, num_heads=4, d_ff=96,
                num_tokens=24, embed_dim=32)
        if self.vision:
            updates["vision"] = dataclasses.replace(
                self.vision, num_layers=2, d_model=64, num_heads=4, d_ff=96,
                num_tokens=8, embed_dim=32)
        if self.action:
            updates["action"] = dataclasses.replace(
                self.action, num_action_tokens=4, dit_layers=2, dit_d_model=32,
                dit_heads=2, dit_steps=2, horizon=2)
        return dataclasses.replace(self, **updates)


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether this (arch, shape) cell runs, and why not if skipped."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "long_500k needs sub-quadratic attention; " \
                      f"{cfg.name} is pure full-attention (see DESIGN.md)"
    return True, ""
