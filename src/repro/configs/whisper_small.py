"""whisper-small [audio] — enc-dec, conv frontend stubbed.

12L d_model=768 12H (kv=12) d_ff=3072 vocab=51865. [arXiv:2212.04356]
The audio conv frontend is a stub: input_specs() provides 1500 precomputed
frame embeddings; the 12-layer encoder tower and 12-layer decoder are real.
"""
from repro.configs.base import ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="encdec",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    d_ff=3072,
    vocab_size=51_865,
    qkv_bias=True,
    norm="layernorm",
    pos="absolute",
    act="gelu_plain",
    tie_embeddings=True,
    encoder=VisionConfig(num_layers=12, d_model=768, num_heads=12, d_ff=3072,
                         num_tokens=1500, embed_dim=768),
)
