"""internvl2-1b [vlm] — InternViT frontend + Qwen2-0.5B-class backbone.
Backbone: 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655.
[arXiv:2404.16821]

The ViT *frontend* (patchify + conv) is a stub: input_specs() provides
precomputed patch embeddings; the vision tower transformer + MLP projector
into the LLM embedding space are real.
"""
from repro.configs.base import ModelConfig, VisionConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151_655,
    qkv_bias=True,
    tie_embeddings=True,
    vision=VisionConfig(num_layers=24, d_model=1024, num_heads=16, d_ff=4096,
                        num_tokens=256, embed_dim=1024),
)
