"""Architecture registry: every assigned arch + the paper's own model.

``get_config(name)`` / ``--arch <id>`` is the single entry point used by the
launcher, dry-run, benchmarks and tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, Iterator, Tuple

from repro.configs.base import (GLOBAL_WINDOW, ActionConfig, ModelConfig,
                                ShapeConfig, SHAPES, VisionConfig,
                                shape_supported)

_MODULES = {
    "whisper-small": "whisper_small",
    "qwen1.5-0.5b": "qwen15_05b",
    "smollm-135m": "smollm_135m",
    "granite-3-2b": "granite_3_2b",
    "gemma3-27b": "gemma3_27b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "arctic-480b": "arctic_480b",
    "internvl2-1b": "internvl2_1b",
    "jamba-1.5-large-398b": "jamba_15_large",
    "mamba2-780m": "mamba2_780m",
    "molmoact-7b": "molmoact_7b",
}

ASSIGNED_ARCHS = tuple(k for k in _MODULES if k != "molmoact-7b")


def get_config(name: str) -> ModelConfig:
    if name == "molmoact-7b-dit":
        return importlib.import_module("repro.configs.molmoact_7b").CONFIG_DIT
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; choices: {sorted(_MODULES)}")
    return importlib.import_module(f"repro.configs.{_MODULES[name]}").CONFIG


def list_archs() -> Tuple[str, ...]:
    return tuple(_MODULES)


def all_configs() -> Dict[str, ModelConfig]:
    return {name: get_config(name) for name in _MODULES}


def cells(include_skipped: bool = False) -> Iterator[Tuple[ModelConfig, ShapeConfig, bool, str]]:
    """Iterate the 40 assigned (arch x shape) cells.

    Yields (cfg, shape, supported, skip_reason)."""
    for arch in ASSIGNED_ARCHS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            ok, why = shape_supported(cfg, shape)
            if ok or include_skipped:
                yield cfg, shape, ok, why


__all__ = [
    "ASSIGNED_ARCHS", "ActionConfig", "GLOBAL_WINDOW", "ModelConfig",
    "SHAPES", "ShapeConfig", "VisionConfig", "all_configs", "cells",
    "get_config", "list_archs", "shape_supported",
]
