"""gemma3-27b [dense] — 62L d_model=5376 32H (GQA kv=16) d_ff=21504
vocab=262144; 5:1 local:global attention (window=1024), 128k context.
[hf:google/gemma-3-*]

The 5:1 interleave makes 5/6 of the layers sub-quadratic, so long_500k is
run for this arch (global layers keep a full-length KV; noted in DESIGN.md).
"""
from repro.configs.base import GLOBAL_WINDOW, ModelConfig

LOCAL_WINDOW = 1024

CONFIG = ModelConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    d_ff=21_504,
    vocab_size=262_144,
    head_dim=128,
    act="gelu",
    rope_theta=1_000_000.0,
    window_pattern=(LOCAL_WINDOW,) * 5 + (GLOBAL_WINDOW,),
    tie_embeddings=True,
)
