"""End-to-end training driver.

    PYTHONPATH=src python -m repro.launch.train --arch smollm-135m \
        --steps 200 --batch 8 --seq 128 --reduced --ckpt /tmp/ckpt

On the CPU container use --reduced (tiny config, real optimization); on a
real TPU fleet drop --reduced and pass --mesh to shard over the production
mesh. Fault tolerance: periodic async checkpoints + ResilientLoop retry /
restore; --simulate-failure N injects a StepFailure at step N to exercise
the path end-to-end.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import Prefetcher, lm_batches
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.training import (AdamWConfig, TrainConfig, init_train_state,
                            make_train_step)
from repro.checkpoint import ResilientLoop, StepFailure, latest_step, restore, store


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="smollm-135m")
    p.add_argument("--steps", type=int, default=100)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=3e-3)
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--ckpt", default="")
    p.add_argument("--save-every", type=int, default=50)
    p.add_argument("--simulate-failure", type=int, default=-1)
    p.add_argument("--log-every", type=int, default=10)
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opts = ModelOptions(remat=False)
    tcfg = TrainConfig(opt=AdamWConfig(lr=args.lr, warmup_steps=10,
                                       total_steps=args.steps),
                       microbatches=args.microbatches)

    params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    opt_state = init_train_state(cfg, tcfg, params)
    step_fn = jax.jit(make_train_step(cfg, opts, tcfg))
    # unbounded stream: failure-replayed steps consume extra batches
    data = Prefetcher(lm_batches(cfg, args.batch, args.seq, steps=None))

    start = 0
    if args.ckpt:
        ck = latest_step(args.ckpt)
        if ck is not None:
            print(f"[train] resuming from step {ck}")
            state0 = restore(args.ckpt, ck,
                             {"params": params, "opt": opt_state})
            params, opt_state = state0["params"], state0["opt"]
            start = ck + 1

    fails = {args.simulate_failure}

    def fault_hook(step):
        if step in fails:
            fails.discard(step)
            raise StepFailure(f"injected at {step}")

    losses = []
    t0 = time.time()

    def one_step(state, step, it):
        params, opt_state = state["params"], state["opt"]
        if fault_hook is not None:
            pass
        batch = {k: jnp.asarray(v) for k, v in next(it).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        loss = float(metrics["loss"])
        losses.append(loss)
        if step % args.log_every == 0:
            dt = time.time() - t0
            print(f"step {step:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"lr {float(metrics['lr']):.2e} ({dt:.1f}s)")
        return {"params": params, "opt": opt_state}

    state = {"params": params, "opt": opt_state}
    if args.ckpt:
        loop = ResilientLoop(one_step, args.ckpt, save_every=args.save_every,
                             fault_hook=fault_hook, async_save=True)
        state, _ = loop.run(state, start, args.steps - start, iter(data))
        print(f"[train] restores={loop.restores}")
    else:
        it = iter(data)
        for s in range(start, args.steps):
            fault_hook(s)
            state = one_step(state, s, it)
    print(f"[train] done: first loss {losses[0]:.4f} -> last {losses[-1]:.4f}")
    return losses


if __name__ == "__main__":
    main()
