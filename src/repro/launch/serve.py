"""Serving driver: continuous-batching engine over synthetic requests,
optionally fronted by the asyncio fleet front-end.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --requests 16 --slots 4 --reduced

    # two replicas behind the async front-end, replaying a Poisson x
    # 10 Hz control-loop fleet trace with prefix-aware routing
    PYTHONPATH=src python -m repro.launch.serve --reduced --paged \
        --chunked-prefill --frontend --replicas 2 --fleet --robots 6

Reports per-request phase latencies (queue / prefill / decode) — the
serving-side counterpart of the paper's phase decomposition — plus
aggregate throughput. Front-end mode adds client-observed TTFT/latency
percentiles, routing and backpressure counters, and control-frequency SLO
attainment (see docs/serving.md for the full flag reference).
"""
from __future__ import annotations

import argparse
import asyncio
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.workload import fleet_trace
from repro.launch.mesh import make_serving_mesh
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.serving import AsyncFrontend, Backpressure, Request, ServingEngine


def _engine_snapshot(eng):
    """Flat float dict for a single bare engine (no front-end): the phase
    report plus the headline counters, list-valued entries expanded to
    indexed keys so the payload stays scrape-flat."""
    snap = {"tokens_decoded": float(eng.stats.tokens_decoded),
            "prefill_tokens": float(eng.stats.prefill_tokens),
            "device_steps": float(eng.stats.device_steps),
            "pages_hwm": float(eng.stats.pages_hwm)}
    for k, v in eng.stats.phase_report().items():
        if isinstance(v, (list, tuple)):
            for j, x in enumerate(v):
                snap[f"{k}_{j}"] = float(x)
        else:
            snap[k] = float(v)
    return snap


def _dump_stats(path: str, snap):
    with open(path, "w") as f:
        json.dump(snap, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"[serve] stats snapshot -> {path} ({len(snap)} keys)")


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen1.5-0.5b")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-tokens", type=int, default=16)
    p.add_argument("--max-seq", type=int, default=128)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--reference", action="store_true",
                   help="per-token decode path instead of the fused tick")
    p.add_argument("--tick-tokens", type=int, default=8)
    p.add_argument("--mesh-model", type=int, default=1,
                   help="shard the engine over a model=N serving mesh: "
                        "attention heads, MLP and the paged KV pool "
                        "partition across N devices, with one lm-head "
                        "all-gather per tick (greedy streams stay "
                        "bit-equal to single-device; heads replicate "
                        "when N does not divide the head counts). "
                        "Requires N visible devices — on CPU set "
                        "XLA_FLAGS=--xla_force_host_platform_device_count=N")
    p.add_argument("--paged", action="store_true",
                   help="paged KV cache (shared page pool + per-slot page "
                        "tables, prefix caching) instead of dense per-slot "
                        "buffers")
    p.add_argument("--page-size", type=int, default=16,
                   help="tokens per KV page (paged mode)")
    p.add_argument("--num-pages", type=int, default=0,
                   help="pool capacity in pages (0 = worst-case sizing)")
    p.add_argument("--kv-dtype", default="bf16",
                   choices=["bf16", "int8", "fp8"],
                   help="paged KV pool storage: bf16 keeps the engine cache "
                        "dtype; int8/fp8 store 1-byte codes with per-page "
                        "scales, shrinking cache_bytes_hwm and decode HBM "
                        "traffic (requires --paged)")
    p.add_argument("--pallas", action="store_true",
                   help="route decode through the flash-decode Pallas "
                        "kernels (dense or paged per --paged); on CPU they "
                        "run in interpret mode, which is slow but exercises "
                        "the real kernel path")
    p.add_argument("--chunked-prefill", action="store_true",
                   help="token-budget scheduler: prompts prefill in fixed "
                        "chunks packed between decode ticks instead of "
                        "admit-stall; prefix-cache hits skip the shared "
                        "prefill compute (see docs/scheduler.md)")
    p.add_argument("--chunk-size", type=int, default=32,
                   help="prefill chunk tokens (must divide by --page-size "
                        "when --paged)")
    p.add_argument("--token-budget", type=int, default=64,
                   help="tokens one tick may spend across decode steps and "
                        "prefill chunks")
    p.add_argument("--spec-decode", action="store_true",
                   help="self-speculative decode: a cheap draft pass of the "
                        "same model proposes spec-k tokens per slot and one "
                        "banded verify chunk checks them all in a single "
                        "full-model pass (greedy only; see "
                        "docs/speculative.md)")
    p.add_argument("--spec-k", type=int, default=4,
                   help="speculation depth: tokens per draft+verify round "
                        "(requires --spec-decode)")
    p.add_argument("--draft-layers", type=int, default=0,
                   help="decoder layers the draft pass runs (0 = half the "
                        "stack; requires --spec-decode)")
    p.add_argument("--draft-quant", default="none",
                   choices=["none", "int8", "fp8"],
                   help="fake-quantize the draft pass's weights to this "
                        "dtype — models a 1-byte-weight draft stream "
                        "(requires --spec-decode)")
    p.add_argument("--stats-json", default="",
                   help="write a flat JSON stats snapshot here on exit "
                        "(frontend mode: AsyncFrontend.stats_snapshot(); "
                        "engine mode: the engine's phase report)")
    p.add_argument("--prefill-band", type=int, default=32,
                   help="key-block size of the banded prefill-with-cache "
                        "attention core: prefill key-axis work covers the "
                        "live prefix rounded up to this block instead of "
                        "max_seq (see docs/scheduler.md)")
    p.add_argument("--frontend", action="store_true",
                   help="drive the engine(s) through the asyncio front-end "
                        "(streaming, cancellation, bounded admission, "
                        "prefix-aware replica routing; see docs/serving.md)")
    p.add_argument("--replicas", type=int, default=1,
                   help="engine replicas behind the front-end (requires "
                        "--frontend)")
    p.add_argument("--queue-limit", type=int, default=64,
                   help="per-replica admission bound: staged + pending "
                        "requests beyond this are rejected with a "
                        "retry-after estimate (requires --frontend)")
    p.add_argument("--inline-ticks", action="store_true",
                   help="tick replicas inline on the event loop instead of "
                        "worker threads: fully deterministic, but replicas "
                        "no longer tick in parallel (requires --frontend)")
    p.add_argument("--fleet", action="store_true",
                   help="replay a Poisson-arrivals x control-loop fleet "
                        "trace in real time instead of the synthetic batch "
                        "(requires --frontend); reports control-frequency "
                        "SLO attainment")
    p.add_argument("--robots", type=int, default=6,
                   help="fleet robots (requires --fleet)")
    p.add_argument("--steps-per-robot", type=int, default=4,
                   help="control-loop steps per robot, episode included "
                        "(requires --fleet)")
    p.add_argument("--control-hz", type=float, default=10.0,
                   help="control-loop frequency: one repeat-observation "
                        "request per robot per period, deadline one period "
                        "(requires --fleet)")
    p.add_argument("--arrival-rate", type=float, default=4.0,
                   help="Poisson robot-arrival rate, robots/s (requires "
                        "--fleet)")
    p.add_argument("--slo-hz", type=float, default=0.0,
                   help="deadline-aware scheduling: target control "
                        "frequency the engine's SLO controller defends — "
                        "realtime requests admit first (EDF within class), "
                        "decode depth and the best-effort prefill-chunk "
                        "quota are derived from slack vs the per-tick EWMA "
                        "wall time, and stalled best-effort prefill may be "
                        "preempted (never realtime). 0 = static budget "
                        "(requires --chunked-prefill)")
    p.add_argument("--priority", default="best_effort",
                   choices=["best_effort", "realtime"],
                   help="scheduling class for synthetic (non-fleet) "
                        "requests; fleet traces carry their own per-request "
                        "classes (control steps are realtime)")
    p.add_argument("--realtime-reserve", type=int, default=0,
                   help="front-end admission slots per replica reserved "
                        "for realtime traffic: best-effort admits against "
                        "queue-limit minus this (requires --frontend)")
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opts = ModelOptions(remat=False, use_pallas=args.pallas,
                        prefill_band=args.prefill_band)
    params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0),
                           jnp.float32)

    mesh = (make_serving_mesh(args.mesh_model)
            if args.mesh_model > 1 else None)

    def make_engine():
        return ServingEngine(cfg, opts, params, n_slots=args.slots,
                             mesh=mesh,
                             max_seq=args.max_seq, eos=-1,
                             fused=not args.reference,
                             tick_tokens=args.tick_tokens,
                             paged=args.paged, page_size=args.page_size,
                             num_pages=args.num_pages or None,
                             kv_dtype=args.kv_dtype,
                             chunked_prefill=args.chunked_prefill,
                             chunk_size=args.chunk_size,
                             token_budget=args.token_budget,
                             spec_decode=args.spec_decode,
                             spec_k=args.spec_k,
                             draft_layers=args.draft_layers or None,
                             draft_quant=(None if args.draft_quant == "none"
                                          else args.draft_quant),
                             slo_hz=args.slo_hz)

    if args.frontend:
        return asyncio.run(_main_frontend(args, cfg, make_engine))
    eng = make_engine()
    rng = np.random.default_rng(0)
    t0 = time.time()
    # synthetic requests all share --priority; a realtime batch gets one
    # SLO period as its deadline when the controller is on
    deadline = (1.0 / args.slo_hz
                if args.slo_hz > 0 and args.priority == "realtime" else 0.0)
    for i in range(args.requests):
        eng.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len,
                                dtype=np.int32),
            max_tokens=args.max_tokens,
            priority=args.priority, deadline_s=deadline))
    done = eng.run()
    wall = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {wall:.2f}s "
          f"({toks / wall:.1f} tok/s aggregate)")
    st = eng.stats
    print(f"[serve] {st.decode_syncs} decode host syncs / "
          f"{st.device_steps} device steps "
          f"({'fused' if not args.reference else 'reference'} path)")
    ph = st.phase_report()
    if st.prefill_key_lanes_full:
        print(f"[serve] banded prefill: band={args.prefill_band} "
              f"key_lane_ratio={ph['prefill_key_lane_ratio']:.3f} "
              f"(banded live-prefix lanes / max_seq-view equivalent)")
    if args.chunked_prefill:
        print(f"[serve] scheduler: chunk={args.chunk_size} "
              f"budget={args.token_budget} "
              f"prefill_tokens={st.prefill_tokens} "
              f"skipped={st.prefill_skipped} "
              f"ttft_mean={np.mean(st.ttft_s):.3f}s "
              f"decode_tick_p99={ph.get('decode_tick_p99', 0.0):.4f}s")
    if args.slo_hz > 0:
        att = {k[len("deadline_attainment_"):]: v for k, v in ph.items()
               if k.startswith("deadline_attainment_")}
        pre = {k[len("preemptions_"):]: v for k, v in ph.items()
               if k.startswith("preemptions_")}
        print(f"[serve] SLO controller: target={args.slo_hz} Hz "
              f"tick_ewma={ph.get('tick_ewma_s', 0.0):.4f}s "
              f"attainment={att or '(no deadlined requests)'} "
              f"preemptions={pre or '{}'}")
    if args.paged:
        print(f"[serve] paged KV: page_size={args.page_size} "
              f"kv_dtype={args.kv_dtype} "
              f"pages_hwm={st.pages_hwm} "
              f"cache_bytes_hwm={st.cache_bytes_hwm} "
              f"prefix_hits={st.prefix_hits}")
    if st.mesh_shape:
        print(f"[serve] mesh: "
              f"{'x'.join(f'{a}={n}' for a, n in st.mesh_shape)} "
              f"cache_bytes_hwm_shard={st.cache_bytes_hwm_shard}")
    if args.spec_decode:
        print(f"[serve] speculative: K={args.spec_k} "
              f"draft_quant={args.draft_quant} "
              f"verify_passes={st.spec_verify_passes} "
              f"accept/pass={ph.get('spec_accept_per_pass', 0.0):.3f} "
              f"draft_frac={ph.get('spec_draft_frac', 0.0):.3f} "
              f"hist={ph.get('spec_accept_hist', [])}")
    if args.stats_json:
        _dump_stats(args.stats_json, _engine_snapshot(eng))
    for r in done[:4]:
        print(f"  req {r.uid}: queue {r.t_prefill - r.t_submit:.3f}s "
              f"decode {r.t_done - r.t_prefill:.3f}s "
              f"({len(r.out_tokens)} tokens)")
    return done


async def _main_frontend(args, cfg, make_engine):
    """Front-end mode: replicas behind AsyncFrontend, fed either the
    synthetic batch or a real-time fleet-trace replay (--fleet)."""
    engines = [make_engine() for _ in range(args.replicas)]
    async with AsyncFrontend(engines, queue_limit=args.queue_limit,
                             offload_ticks=not args.inline_ticks,
                             realtime_reserve=args.realtime_reserve) as fe:
        t0 = time.time()
        if args.fleet:
            # prompt (ctx + 4-token tail) + generated actions must fit the
            # engine's max_seq
            ctx_max = args.max_seq - args.max_tokens - 8
            trace = fleet_trace(n_robots=args.robots,
                                steps_per_robot=args.steps_per_robot,
                                control_hz=args.control_hz,
                                arrival_rate=args.arrival_rate,
                                ctx_max=ctx_max,
                                action_tokens=args.max_tokens,
                                vocab_size=cfg.vocab_size, seed=0)
            served = []         # (event, stream)
            for e in trace:
                delay = e.t - (time.time() - t0)
                if delay > 0:
                    await asyncio.sleep(delay)
                try:
                    served.append((e, await fe.submit(
                        e.prompt, e.max_tokens, priority=e.priority,
                        deadline_s=e.deadline_s)))
                except Backpressure as exc:
                    # a control step re-sent after its period is stale:
                    # drop it, back off for the retry-after estimate —
                    # driven by the replica's measured per-tick EWMA, so
                    # the backoff tightens as ticks speed up instead of
                    # sitting on a fixed cap
                    await asyncio.sleep(exc.retry_after_s)
            streams = [s for _, s in served]
        else:
            rng = np.random.default_rng(0)
            deadline = (1.0 / args.slo_hz
                        if args.slo_hz > 0 and args.priority == "realtime"
                        else 0.0)
            streams = [await fe.submit(
                rng.integers(0, cfg.vocab_size, args.prompt_len,
                             dtype=np.int32), args.max_tokens,
                priority=args.priority, deadline_s=deadline)
                for _ in range(args.requests)]
        for s in streams:
            await s.tokens()
        await fe.drain()
        wall = time.time() - t0
    toks = sum(len(s.request.out_tokens) for s in streams)
    rep = fe.stats.report()
    print(f"[serve] frontend: {rep['completed']} requests, {toks} tokens "
          f"in {wall:.2f}s ({toks / wall:.1f} tok/s aggregate, "
          f"{args.replicas} replica(s))")
    print(f"[serve] routing: prefix={rep['routed_prefix']} "
          f"load={rep['routed_load']} rejected={rep['rejected']} "
          f"cancelled={rep['cancelled']}")
    if "ttft_p50_s" in rep:
        print(f"[serve] client TTFT p50={rep['ttft_p50_s']:.3f}s "
              f"p99={rep['ttft_p99_s']:.3f}s "
              f"latency_p99={rep.get('latency_p99_s', 0.0):.3f}s")
    if args.fleet:
        met = sum(s.t_done - s.t_submit <= e.deadline_s for e, s in served)
        ctrl = [(e, s) for e, s in served if e.kind == "control"]
        ctrl_met = sum(s.t_done - s.t_submit <= e.deadline_s
                       for e, s in ctrl)
        print(f"[serve] fleet SLO: {met}/{len(served)} in deadline "
              f"(control {ctrl_met}/{len(ctrl)} at {args.control_hz} Hz)")
        if args.slo_hz > 0:
            snap = fe.stats_snapshot()
            att = {k: v for k, v in snap.items()
                   if "deadline_attainment" in k or "preemptions" in k}
            print(f"[serve] SLO controller ({args.slo_hz} Hz): {att}")
    for i, eng in enumerate(engines):
        st = eng.stats
        print(f"  replica {i}: decode_tokens={st.tokens_decoded} "
              f"prefill_tokens={st.prefill_tokens} "
              f"skipped={st.prefill_skipped} prefix_hits={st.prefix_hits}")
    if args.stats_json:
        _dump_stats(args.stats_json, fe.stats_snapshot())
    return streams


if __name__ == "__main__":
    main()
