"""Serving driver: continuous-batching engine over synthetic requests.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b \
        --requests 16 --slots 4 --reduced

Reports per-request phase latencies (queue / prefill / decode) — the
serving-side counterpart of the paper's phase decomposition — plus
aggregate throughput.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.serving import Request, ServingEngine


def main(argv=None):
    p = argparse.ArgumentParser()
    p.add_argument("--arch", default="qwen1.5-0.5b")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--slots", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=16)
    p.add_argument("--max-tokens", type=int, default=16)
    p.add_argument("--max-seq", type=int, default=128)
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--reference", action="store_true",
                   help="per-token decode path instead of the fused tick")
    p.add_argument("--tick-tokens", type=int, default=8)
    p.add_argument("--paged", action="store_true",
                   help="paged KV cache (shared page pool + per-slot page "
                        "tables, prefix caching) instead of dense per-slot "
                        "buffers")
    p.add_argument("--page-size", type=int, default=16,
                   help="tokens per KV page (paged mode)")
    p.add_argument("--num-pages", type=int, default=0,
                   help="pool capacity in pages (0 = worst-case sizing)")
    p.add_argument("--kv-dtype", default="bf16",
                   choices=["bf16", "int8", "fp8"],
                   help="paged KV pool storage: bf16 keeps the engine cache "
                        "dtype; int8/fp8 store 1-byte codes with per-page "
                        "scales, shrinking cache_bytes_hwm and decode HBM "
                        "traffic (requires --paged)")
    p.add_argument("--pallas", action="store_true",
                   help="route decode through the flash-decode Pallas "
                        "kernels (dense or paged per --paged); on CPU they "
                        "run in interpret mode, which is slow but exercises "
                        "the real kernel path")
    p.add_argument("--chunked-prefill", action="store_true",
                   help="token-budget scheduler: prompts prefill in fixed "
                        "chunks packed between decode ticks instead of "
                        "admit-stall; prefix-cache hits skip the shared "
                        "prefill compute (see docs/scheduler.md)")
    p.add_argument("--chunk-size", type=int, default=32,
                   help="prefill chunk tokens (must divide by --page-size "
                        "when --paged)")
    p.add_argument("--token-budget", type=int, default=64,
                   help="tokens one tick may spend across decode steps and "
                        "prefill chunks")
    p.add_argument("--prefill-band", type=int, default=32,
                   help="key-block size of the banded prefill-with-cache "
                        "attention core: prefill key-axis work covers the "
                        "live prefix rounded up to this block instead of "
                        "max_seq (see docs/scheduler.md)")
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    opts = ModelOptions(remat=False, use_pallas=args.pallas,
                        prefill_band=args.prefill_band)
    params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    eng = ServingEngine(cfg, opts, params, n_slots=args.slots,
                        max_seq=args.max_seq, eos=-1,
                        fused=not args.reference,
                        tick_tokens=args.tick_tokens,
                        paged=args.paged, page_size=args.page_size,
                        num_pages=args.num_pages or None,
                        kv_dtype=args.kv_dtype,
                        chunked_prefill=args.chunked_prefill,
                        chunk_size=args.chunk_size,
                        token_budget=args.token_budget)
    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        eng.submit(Request(
            uid=i,
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len,
                                dtype=np.int32),
            max_tokens=args.max_tokens))
    done = eng.run()
    wall = time.time() - t0
    toks = sum(len(r.out_tokens) for r in done)
    print(f"[serve] {len(done)} requests, {toks} tokens in {wall:.2f}s "
          f"({toks / wall:.1f} tok/s aggregate)")
    st = eng.stats
    print(f"[serve] {st.decode_syncs} decode host syncs / "
          f"{st.device_steps} device steps "
          f"({'fused' if not args.reference else 'reference'} path)")
    ph = st.phase_report()
    if st.prefill_key_lanes_full:
        print(f"[serve] banded prefill: band={args.prefill_band} "
              f"key_lane_ratio={ph['prefill_key_lane_ratio']:.3f} "
              f"(banded live-prefix lanes / max_seq-view equivalent)")
    if args.chunked_prefill:
        print(f"[serve] scheduler: chunk={args.chunk_size} "
              f"budget={args.token_budget} "
              f"prefill_tokens={st.prefill_tokens} "
              f"skipped={st.prefill_skipped} "
              f"ttft_mean={np.mean(st.ttft_s):.3f}s "
              f"decode_tick_p99={ph.get('decode_tick_p99', 0.0):.4f}s")
    if args.paged:
        print(f"[serve] paged KV: page_size={args.page_size} "
              f"kv_dtype={args.kv_dtype} "
              f"pages_hwm={st.pages_hwm} "
              f"cache_bytes_hwm={st.cache_bytes_hwm} "
              f"prefix_hits={st.prefix_hits}")
    for r in done[:4]:
        print(f"  req {r.uid}: queue {r.t_prefill - r.t_submit:.3f}s "
              f"decode {r.t_done - r.t_prefill:.3f}s "
              f"({len(r.out_tokens)} tokens)")
    return done


if __name__ == "__main__":
    main()
