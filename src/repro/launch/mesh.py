"""Production meshes. A function (not a module-level constant) so importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def _auto_kwargs(n):
    # jax.sharding.AxisType only exists on newer jax; older versions get the
    # default (equivalent) auto axis behaviour with no kwarg at all.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_auto_kwargs(len(axes)))


def make_elastic_mesh(data: int, model: int = 16):
    """Reduced-data-axis mesh for elastic shrink after node loss."""
    return jax.make_mesh((data, model), ("data", "model"), **_auto_kwargs(2))
