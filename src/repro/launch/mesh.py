"""Production meshes. A function (not a module-level constant) so importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def _auto_kwargs(n):
    # jax.sharding.AxisType only exists on newer jax; older versions get the
    # default (equivalent) auto axis behaviour with no kwarg at all.
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def _validate_axes(**sizes):
    for name, n in sizes.items():
        if not isinstance(n, int) or n < 1:
            raise ValueError(f"mesh axis {name!r} must be a positive int, "
                             f"got {n!r}")
    total = 1
    for n in sizes.values():
        total *= n
    avail = jax.device_count()
    if total > avail:
        raise ValueError(
            f"mesh {dict(sizes)} needs {total} devices but only {avail} "
            f"are visible (CPU runs: set XLA_FLAGS="
            f"--xla_force_host_platform_device_count=N before importing jax)")


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    _validate_axes(**dict(zip(axes, shape)))
    return jax.make_mesh(shape, axes, **_auto_kwargs(len(axes)))


def make_elastic_mesh(data: int, model: int = 16):
    """Reduced-data-axis mesh for elastic shrink after node loss."""
    _validate_axes(data=data, model=model)
    return jax.make_mesh((data, model), ("data", "model"), **_auto_kwargs(2))


def make_serving_mesh(model: int):
    """1-axis ('model',) mesh for tensor-parallel serving — sized for dev
    boxes and CI, not just the 16x16 production shapes (the old factories
    hardcoded model=16, so any small-mesh user had to monkey-patch).
    ``ServingEngine(mesh=...)`` shards attention heads, MLP width, vocab,
    and the paged KV pool's head axis over it (see docs/architecture.md)."""
    _validate_axes(model=model)
    return jax.make_mesh((model,), ("model",), **_auto_kwargs(1))


def make_dev_mesh(data: int = 1, model: int = 2):
    """Small 2-axis mesh for tests/examples on a dev box; validates against
    the visible device count instead of assuming a 256-chip slice."""
    _validate_axes(data=data, model=model)
    return jax.make_mesh((data, model), ("data", "model"), **_auto_kwargs(2))
