"""Production meshes. A function (not a module-level constant) so importing
this module never touches jax device state."""
from __future__ import annotations

import jax


def _auto(n):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_elastic_mesh(data: int, model: int = 16):
    """Reduced-data-axis mesh for elastic shrink after node loss."""
    return jax.make_mesh((data, model), ("data", "model"), axis_types=_auto(2))
