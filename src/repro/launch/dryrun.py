import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: AOT lower + compile every (arch x shape) cell on the
production mesh, proving the distribution config is coherent, and record
memory_analysis / cost_analysis / collective traffic for §Roofline.

    PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-27b \
        --shape train_4k [--multi-pod] [--out artifacts/dryrun]

The XLA_FLAGS line above MUST stay the first statement: jax locks the
device count on first init, and the dry-run needs 512 placeholder host
devices. Smoke tests and benchmarks import repro.* directly and see 1.
"""
import argparse
import dataclasses
import json
import time
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, get_config, shape_supported
from repro.distributed.sharding import (DEFAULT_RULES, INFERENCE_RULES,
                                        SEQ_PARALLEL_RULES, global_mesh)
from repro.launch import specs as SP
from repro.launch.mesh import make_production_mesh
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.roofline import collective_bytes, model_flops_for
from repro.roofline.analytic import analytic_cell
from repro.training import (AdamWConfig, TrainConfig, init_train_state,
                            make_train_step)


def build_step(cfg, shape, opts: ModelOptions, tcfg: TrainConfig):
    """Returns (fn, arg_specs, arg_shardings, donate) for the cell."""

    if shape.kind == "train":
        step = make_train_step(cfg, opts, tcfg)
        return step, ("params", "opt_state", "batch"), (0, 1)
    if shape.kind == "prefill":
        def prefill_step(params, batch):
            return M.prefill(cfg, opts, params, batch, shape.seq_len,
                             cache_dtype=SP.CACHE_DTYPE)
        return prefill_step, ("params", "batch"), ()
    def serve_step(params, token, caches, index):
        return M.decode_step(cfg, opts, params, token, caches, index)
    return serve_step, ("params", "token", "caches", "index"), (2,)


def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             out_dir: Optional[str] = None, opts: Optional[ModelOptions] = None,
             microbatches: int = 1, moment_dtype: str = "float32",
             infer_rules: bool = False, seq_parallel: bool = False,
             pad_experts: int = 0, tag: str = "", verbose: bool = True) -> dict:
    cfg = get_config(arch)
    if pad_experts:
        cfg = dataclasses.replace(cfg, num_experts_padded=pad_experts)
    shape = SHAPES[shape_name]
    ok, why = shape_supported(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name, "skipped": why}

    opts = opts or ModelOptions()
    tcfg = TrainConfig(opt=AdamWConfig(moment_dtype=getattr(jnp, moment_dtype)),
                       microbatches=microbatches)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "multi_pod" if multi_pod else "single_pod"
    rules = dict(DEFAULT_RULES)
    if infer_rules:
        rules.update(INFERENCE_RULES)
    if seq_parallel:
        rules.update({k: v for k, v in SEQ_PARALLEL_RULES.items()
                      if k == "act_seq"})

    with global_mesh(mesh, rules=rules):
        params_sds, params_sh = SP.model_specs_and_shardings(cfg, mesh)
        in_sds = SP.input_specs(cfg, shape, opts)
        in_sh = SP.input_shardings(cfg, shape, mesh, opts)
        fn, order, donate = build_step(cfg, shape, opts, tcfg)

        args, shardings = [], []
        for name in order:
            if name == "params":
                args.append(params_sds)
                shardings.append(params_sh)
            elif name == "opt_state":
                opt_sds = jax.eval_shape(
                    lambda p: init_train_state(cfg, tcfg, p), params_sds)
                repl = jax.sharding.NamedSharding(
                    mesh, jax.sharding.PartitionSpec())
                opt_sh = {"inner": {"mu": params_sh, "nu": params_sh,
                                    "count": repl}}
                if tcfg.compress_grads:
                    opt_sh["error"] = params_sh
                args.append(opt_sds)
                shardings.append(opt_sh)
            else:
                args.append(in_sds[name])
                shardings.append(in_sh[name])

        t0 = time.time()
        jitted = jax.jit(fn, in_shardings=tuple(shardings),
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if not isinstance(cost, dict):
        cost = cost[0] if cost else {}
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    mem_d = {k: float(getattr(mem, k)) for k in (
        "argument_size_in_bytes", "output_size_in_bytes",
        "temp_size_in_bytes", "alias_size_in_bytes")} if mem else {}
    ac = analytic_cell(cfg, shape, multi_pod=multi_pod,
                       causal_pairs=opts.causal_pairs,
                       window_cache=opts.window_cache, remat=opts.remat,
                       microbatches=microbatches, infer_rules=infer_rules,
                       seq_parallel=seq_parallel)
    row = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name, "tag": tag,
        "kind": shape.kind,
        "cost": {k: float(v) for k, v in cost.items()
                 if k in ("flops", "bytes accessed", "transcendentals")},
        "memory": mem_d,
        "collectives": coll,
        "analytic": {"flops_per_dev": ac.flops_per_dev,
                     "hbm_bytes_per_dev": ac.hbm_bytes_per_dev,
                     "coll_bytes_per_dev": ac.coll_bytes_per_dev,
                     "breakdown": ac.breakdown},
        "model_flops": model_flops_for(cfg, shape),
        "params_total": cfg.param_counts()["total"],
        "params_active": cfg.param_counts()["active"],
        "t_lower_s": t_lower, "t_compile_s": t_compile,
        "hlo_bytes": len(hlo),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {mesh_name}: "
              f"flops/dev={row['cost'].get('flops', 0):.3e} "
              f"bytes/dev={row['cost'].get('bytes accessed', 0):.3e} "
              f"coll/dev={coll.get('total', 0):.3e} "
              f"temp/dev={mem_d.get('temp_size_in_bytes', 0)/2**30:.2f}GiB "
              f"(lower {t_lower:.0f}s, compile {t_compile:.0f}s)")
        print("memory_analysis:", mem)
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
        suffix = f"-{tag}" if tag else ""
        fname = f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
        with open(os.path.join(out_dir, fname), "w") as f:
            json.dump(row, f, indent=1)
    return row


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", required=True, choices=list(SHAPES))
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--out", default="artifacts/dryrun")
    p.add_argument("--microbatches", type=int, default=1)
    p.add_argument("--tag", default="")
    p.add_argument("--causal-pairs", action="store_true")
    p.add_argument("--window-cache", action="store_true")
    p.add_argument("--no-remat", action="store_true")
    p.add_argument("--unroll", action="store_true",
                   help="unroll the layer scan (exact XLA cost analysis)")
    p.add_argument("--infer-rules", action="store_true",
                   help="inference sharding rules (no FSDP; see §Perf)")
    p.add_argument("--seq-parallel", action="store_true",
                   help="sequence-parallel TP residual sharding (see §Perf)")
    p.add_argument("--moe-per-seq", action="store_true",
                   help="per-sequence-local MoE dispatch (see §Perf)")
    p.add_argument("--pad-experts", type=int, default=0,
                   help="pad expert dim to divide the TP axis (see §Perf)")
    p.add_argument("--moe-gather", action="store_true",
                   help="tiny-batch decode: gather top-k expert weights")
    p.add_argument("--remat-sublayers", action="store_true",
                   help="nested per-sublayer remat (see §Perf)")
    args = p.parse_args()
    opts = ModelOptions(causal_pairs=args.causal_pairs,
                        window_cache=args.window_cache,
                        remat=not args.no_remat,
                        moe_per_seq_dispatch=args.moe_per_seq,
                        moe_gather_decode=args.moe_gather,
                        remat_sublayers=args.remat_sublayers,
                        unroll_layers=args.unroll)
    row = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                   out_dir=args.out, opts=opts,
                   microbatches=args.microbatches, tag=args.tag,
                   infer_rules=args.infer_rules,
                   seq_parallel=args.seq_parallel,
                   pad_experts=args.pad_experts)
    if "skipped" in row:
        print(f"[dryrun] SKIP {args.arch} x {args.shape}: {row['skipped']}")


if __name__ == "__main__":
    main()
