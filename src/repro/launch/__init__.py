from repro.launch.mesh import make_elastic_mesh, make_production_mesh

__all__ = ["make_elastic_mesh", "make_production_mesh"]
