"""Run the full dry-run sweep: every (arch x shape) cell on single-pod and
multi-pod meshes, one subprocess per cell (fresh XLA state, bounded memory).

    PYTHONPATH=src python -m repro.launch.sweep --out artifacts/dryrun
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

from repro.configs import ASSIGNED_ARCHS, SHAPES, get_config, shape_supported


def cell_done(out_dir: str, arch: str, shape: str, mesh: str, tag: str = "") -> bool:
    suffix = f"-{tag}" if tag else ""
    return os.path.exists(os.path.join(
        out_dir, f"{arch}__{shape}__{mesh}{suffix}.json"))


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--out", default="artifacts/dryrun")
    p.add_argument("--meshes", default="single_pod,multi_pod")
    p.add_argument("--archs", default=",".join(ASSIGNED_ARCHS))
    p.add_argument("--shapes", default=",".join(SHAPES))
    p.add_argument("--timeout", type=int, default=3000)
    p.add_argument("--skip-done", action="store_true", default=True)
    args = p.parse_args()

    os.makedirs(args.out, exist_ok=True)
    skipped, failed, ok = [], [], []
    t00 = time.time()
    for mesh in args.meshes.split(","):
        for arch in args.archs.split(","):
            cfg = get_config(arch)
            for shape in args.shapes.split(","):
                sup, why = shape_supported(cfg, SHAPES[shape])
                if not sup:
                    skipped.append((arch, shape, why))
                    continue
                if args.skip_done and cell_done(args.out, arch, shape, mesh):
                    ok.append((arch, shape, mesh, "cached"))
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--out", args.out]
                if mesh == "multi_pod":
                    cmd.append("--multi-pod")
                t0 = time.time()
                try:
                    r = subprocess.run(cmd, capture_output=True, text=True,
                                       timeout=args.timeout,
                                       env={**os.environ, "PYTHONPATH": "src"})
                    dt = time.time() - t0
                    if r.returncode == 0:
                        ok.append((arch, shape, mesh, f"{dt:.0f}s"))
                        print(f"OK   {arch} x {shape} x {mesh} ({dt:.0f}s)",
                              flush=True)
                    else:
                        failed.append((arch, shape, mesh,
                                       r.stderr.strip().splitlines()[-1]
                                       if r.stderr.strip() else "?"))
                        print(f"FAIL {arch} x {shape} x {mesh}:\n"
                              + "\n".join(r.stderr.strip().splitlines()[-15:]),
                              flush=True)
                except subprocess.TimeoutExpired:
                    failed.append((arch, shape, mesh, "timeout"))
                    print(f"TIMEOUT {arch} x {shape} x {mesh}", flush=True)
    print(f"\n=== sweep done in {(time.time()-t00)/60:.1f} min: "
          f"{len(ok)} ok, {len(failed)} failed, {len(skipped)} skipped ===")
    for f in failed:
        print("FAILED:", f)
    for s in skipped:
        print("SKIPPED:", s)
    with open(os.path.join(args.out, "_sweep_summary.json"), "w") as f:
        json.dump({"ok": ok, "failed": failed, "skipped": skipped}, f, indent=1)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
