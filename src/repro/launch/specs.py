"""ShapeDtypeStruct input stand-ins + shardings for every (arch x shape)
cell — the AOT surface the dry-run lowers against (no device allocation).
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.distributed.sharding import spec_for
from repro.models import model as M
from repro.models import stacks
from repro.models.layers import ModelOptions
from repro.models.params import PSpec, param_shapes, param_shardings

CACHE_DTYPE = jnp.bfloat16
PARAM_DTYPE = jnp.bfloat16


def text_len(cfg: ModelConfig, total_seq: int) -> int:
    """Text-token count once the vision prefix is folded into the sequence."""
    if cfg.vision is not None:
        return total_seq - cfg.vision.num_tokens
    return total_seq


def batch_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, jax.ShapeDtypeStruct]:
    B = shape.global_batch
    S = text_len(cfg, shape.seq_len)
    out = {"tokens": jax.ShapeDtypeStruct((B, S), jnp.int32)}
    if cfg.vision is not None:
        out["patches"] = jax.ShapeDtypeStruct(
            (B, cfg.vision.num_tokens, cfg.vision.embed_dim), PARAM_DTYPE)
    if cfg.encoder is not None:
        out["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder.num_tokens, cfg.encoder.embed_dim), PARAM_DTYPE)
    return out


def batch_axes(cfg: ModelConfig) -> Dict[str, Tuple[Optional[str], ...]]:
    out = {"tokens": ("batch", "act_seq")}
    if cfg.vision is not None:
        out["patches"] = ("batch", None, None)
    if cfg.encoder is not None:
        out["frames"] = ("batch", None, None)
    return out


def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                opts: Optional[ModelOptions] = None) -> Dict:
    """All inputs for the cell's step function, as ShapeDtypeStructs.

    train/prefill: {'batch': ...}
    decode:        {'token', 'caches', 'index'} with a seq_len-deep cache.
    """
    opts = opts or ModelOptions()
    if shape.kind in ("train", "prefill"):
        return {"batch": batch_specs(cfg, shape)}
    B = shape.global_batch
    t = stacks.cache_template(cfg, B, shape.seq_len, CACHE_DTYPE, opts)
    caches = jax.tree_util.tree_map_with_path(
        lambda path, s: jax.ShapeDtypeStruct(
            s.shape, stacks.cache_dtype(path[-1].key, CACHE_DTYPE)),
        t, is_leaf=lambda x: isinstance(x, PSpec))
    return {
        "token": jax.ShapeDtypeStruct((B, 1), jnp.int32),
        "caches": caches,
        "index": jax.ShapeDtypeStruct((), jnp.int32),
    }


def input_shardings(cfg: ModelConfig, shape: ShapeConfig, mesh,
                    opts: Optional[ModelOptions] = None):
    """NamedShardings matching input_specs."""
    from jax.sharding import NamedSharding

    opts = opts or ModelOptions()
    if shape.kind in ("train", "prefill"):
        specs = batch_specs(cfg, shape)
        axes = batch_axes(cfg)
        return {"batch": {
            k: NamedSharding(mesh, spec_for(specs[k].shape, axes[k], mesh))
            for k in specs}}
    t = stacks.cache_template(cfg, shape.global_batch, shape.seq_len,
                              CACHE_DTYPE, opts)
    caches = jax.tree.map(
        lambda s: NamedSharding(mesh, spec_for(s.shape, s.axes, mesh)),
        t, is_leaf=lambda x: isinstance(x, PSpec))
    return {
        "token": NamedSharding(
            mesh, spec_for((shape.global_batch, 1), ("batch", None), mesh)),
        "caches": caches,
        "index": NamedSharding(mesh, spec_for((), (), mesh)),
    }


def model_specs_and_shardings(cfg: ModelConfig, mesh,
                              dtype=PARAM_DTYPE):
    template = M.model_template(cfg)
    return param_shapes(template, dtype), param_shardings(template, mesh)
