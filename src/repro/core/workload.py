"""Workloads: the operator-level IR for the XPU simulator, and the fleet
traffic generator for the serving front-end.

**Operator IR.** A VLA inference step is decomposed exactly as the paper's
Figure 1: vision encoding -> generation (prefill + autoregressive CoT
decode) -> action generation (action-token decode or DiT iterations). Each
phase is a list of ``Op``s (einsum-level granularity, like the paper's
simulator), with FLOPs and bytes derived analytically from the ModelConfig.

**Fleet traces** (``fleet_trace``). The serving front-end's workload is a
robot fleet, not a static request list: robots join as a Poisson process,
each then runs a periodic control loop (the paper's fig3 control-frequency
scenarios — 10 Hz is the canonical target) whose every step resubmits the
robot's observation context plus a small per-step delta, and context
lengths are long-tailed across robots. The generator is deterministic per
seed, so a trace is a reproducible benchmark input (same seed -> the same
arrival times, prompts, and deadlines, bit for bit).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np

from repro.configs.base import GLOBAL_WINDOW, ModelConfig

BYTES = 2  # bf16 weights/activations


@dataclass(frozen=True)
class Op:
    name: str
    kind: str                 # 'gemm' | 'gemv' | 'attn' | 'elementwise'
    flops: float
    weight_bytes: float       # streamed parameters (incl. KV/SSM state reads)
    act_bytes: float          # activations in+out

    @property
    def bytes(self) -> float:
        return self.weight_bytes + self.act_bytes

    @property
    def intensity(self) -> float:
        return self.flops / max(self.bytes, 1.0)


@dataclass
class Phase:
    name: str
    ops: List[Op] = field(default_factory=list)
    repeat: int = 1           # e.g. decode steps

    def add(self, *ops: Op):
        self.ops.extend(ops)

    @property
    def flops(self) -> float:
        return self.repeat * sum(o.flops for o in self.ops)

    @property
    def bytes(self) -> float:
        return self.repeat * sum(o.bytes for o in self.ops)


def _gemm(name: str, m: int, k: int, n: int, batch: int = 1,
          weight: bool = True, kind: Optional[str] = None) -> Op:
    """[m,k]x[k,n] (xbatch). GEMV when the streaming dim is tiny."""
    flops = 2.0 * batch * m * k * n
    wb = batch * k * n * BYTES if weight else 0.0
    ab = batch * (m * k + m * n) * BYTES + (0.0 if weight else batch * k * n * BYTES)
    return Op(name, kind or ("gemv" if m <= 8 else "gemm"), flops, wb, ab)


def _expected_experts_hit(E: int, k: int, tokens: int) -> float:
    """Expected number of distinct experts activated by `tokens` tokens
    with top-k routing (uniform assumption)."""
    return E * (1.0 - (1.0 - k / E) ** tokens)


# ---------------------------------------------------------------------------
# per-component builders
# ---------------------------------------------------------------------------

def tower_ops(cfg: ModelConfig, tower, B: int, tag: str) -> List[Op]:
    d, n, f, T = tower.d_model, tower.num_heads, tower.d_ff, tower.num_tokens
    ops = [_gemm(f"{tag}/in_proj", B * T, tower.embed_dim, d)]
    per_layer = [
        _gemm(f"{tag}/qkv", B * T, d, 3 * d),
        Op(f"{tag}/attn", "attn", 2 * 2.0 * B * n * T * T * (d // n),
           0.0, B * (2 * T * d + n * T * T) * BYTES),
        _gemm(f"{tag}/attn_out", B * T, d, d),
        _gemm(f"{tag}/mlp_up", B * T, d, f),
        _gemm(f"{tag}/mlp_down", B * T, f, d),
    ]
    for l in per_layer:
        ops.append(dataclasses.replace(l, flops=l.flops * tower.num_layers,
                                       weight_bytes=l.weight_bytes * tower.num_layers,
                                       act_bytes=l.act_bytes * tower.num_layers))
    ops.append(_gemm(f"{tag}/out_proj", B * T, d, cfg.d_model))
    return ops


def _layer_ops(cfg: ModelConfig, i: int, B: int, S: int, ctx: int,
               decode: bool, causal_half: bool = True) -> List[Op]:
    """Ops for decoder layer i processing S new tokens against `ctx` history.

    causal_half=False models an implementation that computes the full S^2
    score matrix with masking (our baseline flash_ref path); True models a
    causal-skipping schedule (the causal_pairs optimization / real kernels).
    """
    d, hd = cfg.d_model, cfg.head_dim
    N, K = cfg.num_heads, cfg.num_kv_heads
    m = B * S
    ops: List[Op] = []
    if cfg.is_attn_layer(i):
        w = cfg.layer_window(i)
        kv_len = ctx if w == GLOBAL_WINDOW else min(ctx, w + 512)
        ops.append(_gemm(f"L{i}/wq", m, d, N * hd))
        ops.append(_gemm(f"L{i}/wkv", m, d, 2 * K * hd))
        # scores+out: decode reads the KV cache (counted as streamed bytes)
        attn_flops = 2 * 2.0 * B * N * S * kv_len * hd
        if not decode and w == GLOBAL_WINDOW and causal_half:
            attn_flops *= 0.5  # causal
        kv_bytes = B * kv_len * K * hd * 2 * BYTES
        ops.append(Op(f"L{i}/attn", "attn", attn_flops, kv_bytes,
                      m * N * hd * 2 * BYTES))
        ops.append(_gemm(f"L{i}/wo", m, N * hd, d))
    elif cfg.family in ("ssm", "hybrid"):
        d_in = cfg.ssm_expand * d
        H = d_in // cfg.ssm_head_dim
        Nst = cfg.ssm_state
        conv_ch = d_in + 2 * Nst
        ops.append(_gemm(f"L{i}/ssm_in", m, d, d_in + conv_ch + H))
        ops.append(Op(f"L{i}/conv1d", "elementwise",
                      2.0 * m * conv_ch * cfg.ssm_conv,
                      cfg.ssm_conv * conv_ch * BYTES, 2 * m * conv_ch * BYTES))
        # SSD: state update + output (decode: one recurrence over state)
        state_bytes = B * H * (d_in // H) * Nst * 4  # fp32 state read+write
        ssd_flops = 2.0 * m * d_in * Nst * 2
        if not decode:
            Q = 128  # intra-chunk quadratic term
            ssd_flops += 2.0 * B * (S // max(Q, 1) or 1) * Q * Q * H * (d_in // H)
        ops.append(Op(f"L{i}/ssd", "gemv" if decode else "attn",
                      ssd_flops, 2 * state_bytes, 2 * m * d_in * BYTES))
        ops.append(_gemm(f"L{i}/ssm_out", m, d_in, d))
    if cfg.family == "encdec" and cfg.is_attn_layer(i):
        T_enc = cfg.encoder.num_tokens
        ops.append(_gemm(f"L{i}/xq", m, d, N * hd))
        ops.append(Op(f"L{i}/xattn", "attn", 2 * 2.0 * B * N * S * T_enc * hd,
                      B * T_enc * K * hd * 2 * BYTES, m * N * hd * 2 * BYTES))
        ops.append(_gemm(f"L{i}/xo", m, N * hd, d))
    # FFN
    if cfg.is_moe_layer(i):
        E, k, f = cfg.num_experts, cfg.top_k, cfg.moe_d_ff
        ops.append(_gemm(f"L{i}/router", m, d, E))
        # weights streamed = distinct experts hit; flops = routed tokens
        hit = _expected_experts_hit(E, k, m)
        flops = 2.0 * m * k * d * f * 3
        wbytes = hit * 3 * d * f * BYTES
        ops.append(Op(f"L{i}/moe", "gemv" if m * k <= E * 8 else "gemm",
                      flops, wbytes, 2 * m * d * BYTES * k))
        if cfg.dense_residual and cfg.d_ff:
            ops.append(_gemm(f"L{i}/mlp_up", m, d, 2 * cfg.d_ff))
            ops.append(_gemm(f"L{i}/mlp_down", m, cfg.d_ff, d))
    elif cfg.d_ff and cfg.family != "ssm":
        gate = 2 if cfg.act in ("silu", "gelu") else 1
        ops.append(_gemm(f"L{i}/mlp_up", m, d, gate * cfg.d_ff))
        ops.append(_gemm(f"L{i}/mlp_down", m, cfg.d_ff, d))
    return ops


def decoder_ops(cfg: ModelConfig, B: int, S: int, ctx: int,
                decode: bool, tag: str, causal_half: bool = True) -> List[Op]:
    ops: List[Op] = []
    for i in range(cfg.num_layers):
        for o in _layer_ops(cfg, i, B, S, ctx, decode, causal_half):
            ops.append(dataclasses.replace(o, name=f"{tag}/{o.name}"))
    m = B * S
    ops.append(_gemm(f"{tag}/lm_head", m, cfg.d_model, cfg.vocab_size))
    return ops


# ---------------------------------------------------------------------------
# the VLA step (paper Fig. 1)
# ---------------------------------------------------------------------------

def build_vla_step(cfg: ModelConfig, B: int = 1) -> List[Phase]:
    """Phases of one control step: vision -> generation -> action."""
    phases: List[Phase] = []
    n_vis = cfg.vision.num_tokens if cfg.vision else 0
    n_enc = cfg.encoder.num_tokens if cfg.encoder else 0

    vision = Phase("vision_encode")
    if cfg.vision:
        vision.add(*tower_ops(cfg, cfg.vision, B, "vision"))
    if cfg.encoder:
        vision.add(*tower_ops(cfg, cfg.encoder, B, "audio"))
    phases.append(vision)

    prompt = n_vis + cfg.n_prompt_tokens
    gen = Phase("generation_prefill")
    gen.add(*decoder_ops(cfg, B, prompt, prompt, decode=False, tag="prefill"))
    phases.append(gen)

    dec = Phase("generation_decode", repeat=cfg.n_cot_tokens)
    dec.add(*decoder_ops(cfg, B, 1, prompt + cfg.n_cot_tokens // 2,
                         decode=True, tag="decode"))
    phases.append(dec)

    act = Phase("action_generate")
    a = cfg.action
    if a is None or a.mode == "discrete":
        n_act = a.num_action_tokens if a else 24
        act.repeat = n_act
        act.add(*decoder_ops(cfg, B, 1, prompt + cfg.n_cot_tokens,
                             decode=True, tag="action"))
    else:
        act.repeat = a.dit_steps
        dd, dh, dn = a.dit_d_model, a.horizon, a.dit_heads
        per_layer = [
            _gemm("dit/qkv", B * dh, dd, 3 * dd),
            Op("dit/attn", "attn", 2 * 2.0 * B * dn * dh * dh * (dd // dn),
               0.0, B * 3 * dh * dd * BYTES),
            _gemm("dit/proj", B * dh, dd, dd),
            _gemm("dit/mlp_up", B * dh, dd, 4 * dd),
            _gemm("dit/mlp_down", B * dh, 4 * dd, dd),
        ]
        for l in per_layer:
            act.add(dataclasses.replace(
                l, flops=l.flops * a.dit_layers,
                weight_bytes=l.weight_bytes * a.dit_layers,
                act_bytes=l.act_bytes * a.dit_layers))
    phases.append(act)
    return phases


def workload_totals(phases: List[Phase]) -> Dict[str, float]:
    return {
        "flops": sum(p.flops for p in phases),
        "bytes": sum(p.bytes for p in phases),
    }


# ---------------------------------------------------------------------------
# fleet traffic traces (serving front-end workloads)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class FleetRequest:
    """One request of a fleet trace, in arrival order.

    ``t`` is the arrival offset from trace start (seconds; the replayer
    submits at ``t`` and measures SLO attainment against ``t +
    deadline_s``). ``kind`` is ``"episode"`` for a robot's first request
    (cold prefix — the prompt's context pages are not in any pool yet) and
    ``"control"`` for the periodic repeats, whose prompt shares the
    robot's full context prefix and differs only in the last
    ``tail`` positions — the repeat-observation pattern the prefix cache
    and the front-end's replica routing are built around."""
    t: float
    robot: int
    step: int                 # control-loop step index (0 = episode start)
    kind: str                 # "episode" | "control"
    prompt: np.ndarray        # [ctx + tail] int32
    max_tokens: int           # action chunk length to decode
    deadline_s: float         # complete within t + deadline_s (SLO)
    priority: str = "best_effort"   # scheduling class: control-loop
    #                                 repeats are "realtime" (the robot is
    #                                 waiting on its action chunk),
    #                                 episode starts "best_effort"


def fleet_trace(n_robots: int = 8,
                steps_per_robot: int = 5,
                control_hz: float = 10.0,
                arrival_rate: float = 4.0,
                ctx_median: int = 32,
                ctx_sigma: float = 0.6,
                ctx_max: int = 96,
                tail: int = 4,
                action_tokens: int = 8,
                vocab_size: int = 1000,
                seed: int = 0) -> List[FleetRequest]:
    """Deterministic robot-fleet trace: Poisson arrivals x periodic control
    loops x long-tail context lengths.

    - **Arrivals.** Robot ``r`` joins at the r-th event of a Poisson
      process with rate ``arrival_rate`` robots/s (exponential
      inter-arrival times).
    - **Control loop.** From its join time, each robot issues
      ``steps_per_robot`` requests at period ``1 / control_hz``. Every
      request's prompt is the robot's fixed context (camera frame +
      instruction surrogate) followed by ``tail`` fresh per-step tokens;
      step 0 is the cold ``"episode"`` request, later steps are
      ``"control"`` repeats whose context prefix is prefix-cache shareable.
    - **Long-tail lengths.** Context lengths are lognormal
      (``ctx_median`` median, ``ctx_sigma`` log-stdev), clipped to
      ``[tail + 1, ctx_max]`` — a few robots carry much longer contexts
      than the median, the tail that makes admission policy matter.
    - **Deadlines & classes.** Control requests must complete within one
      control period (produce the action chunk before the next
      observation) and carry the ``"realtime"`` priority class — the
      SLO-aware scheduler admits them first and defends their deadline;
      episode requests get 10 periods and stay ``"best_effort"``
      (episode startup is not latency-critical at the control rate).

    Returns the trace sorted by arrival time (ties broken by robot id,
    then step — total order, so replay order is deterministic too). All
    randomness flows from one ``np.random.default_rng(seed)``: the same
    arguments give the same trace, bit for bit, on any platform numpy
    supports (gated by a seeded-replay unit test).
    """
    if n_robots < 1:
        raise ValueError(f"n_robots must be >= 1, got {n_robots}")
    if control_hz <= 0 or arrival_rate <= 0:
        raise ValueError("control_hz and arrival_rate must be positive")
    rng = np.random.default_rng(seed)
    period = 1.0 / control_hz
    trace: List[FleetRequest] = []
    t_join = 0.0
    for r in range(n_robots):
        t_join += float(rng.exponential(1.0 / arrival_rate))
        ctx_len = int(np.clip(
            np.rint(rng.lognormal(np.log(ctx_median), ctx_sigma)),
            tail + 1, ctx_max))
        ctx = rng.integers(0, vocab_size, ctx_len, dtype=np.int32)
        for step in range(steps_per_robot):
            prompt = np.concatenate(
                [ctx, rng.integers(0, vocab_size, tail, dtype=np.int32)])
            trace.append(FleetRequest(
                t=t_join + step * period,
                robot=r,
                step=step,
                kind="episode" if step == 0 else "control",
                prompt=prompt,
                max_tokens=action_tokens,
                deadline_s=period if step else 10 * period,
                priority="realtime" if step else "best_effort"))
    trace.sort(key=lambda e: (e.t, e.robot, e.step))
    return trace
