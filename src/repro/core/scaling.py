"""Scaling-law model family (paper §4.2: "We scale VLA models upto 100B
parameters, following scaling laws in [1, 8]").

Width and depth are scaled jointly (depth ~ N^(1/3), width to hit the target
count), keeping the MolmoAct/Qwen2 architectural ratios: d_ff ~ 5.3*d,
head_dim=128, GQA 7:1, and the same vision tower + phase lengths.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from repro.configs import get_config
from repro.configs.base import ModelConfig


def scaled_vla(target_params: float, base: str = "molmoact-7b") -> ModelConfig:
    cfg = get_config(base)
    base_n = cfg.param_counts()["total"]
    ratio = target_params / base_n
    L = max(8, int(round(cfg.num_layers * ratio ** (1 / 3))))
    # pick width (multiple of 256) to hit the target under depth L
    best = None
    for d in range(1024, 20481, 256):
        heads = max(4, d // 128)
        kv = max(1, heads // 7)
        heads = kv * (heads // kv)
        c = dataclasses.replace(
            cfg, name=f"vla-{target_params/1e9:.0f}b",
            num_layers=L, d_model=d, num_heads=heads, num_kv_heads=kv,
            head_dim=128, d_ff=int(round(d * 5.3 / 256) * 256))
        n = c.param_counts()["total"]
        err = abs(n - target_params) / target_params
        if best is None or err < best[0]:
            best = (err, c)
    return best[1]


def scaling_sweep(sizes=(7e9, 14e9, 30e9, 50e9, 70e9, 100e9)) -> List[ModelConfig]:
    out = []
    for s in sizes:
        if abs(s - 7e9) / 7e9 < 0.15:
            out.append(get_config("molmoact-7b"))
        else:
            out.append(scaled_vla(s))
    return out
