"""Hardware catalog: the paper's Table 1 edge platforms + hypothetical
variants + the TPU v5e target this framework compiles for.

PIM modeling (paper §3.2 / Table 1): the BF16 TFLOPS of PIM systems includes
SoC + PIM. Memory-bound GEMV-class operators (arithmetic intensity below the
PIM cutoff) execute in-memory at the PIM bank bandwidth with the PIM share of
compute; everything else runs on the SoC at the external interface bandwidth.
External BW for LPDDR6X host interface is assumed 2x LPDDR5X (546 GB/s) —
an assumption recorded here because the paper does not state it.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional


@dataclass(frozen=True)
class Hardware:
    name: str
    mem_bw_gbs: float            # external memory bandwidth, GB/s
    bf16_tflops: float           # SoC peak BF16
    # efficiency knobs (calibrated against the paper's measured ratios)
    gemm_eff: float = 0.40       # achievable fraction of peak for big GEMMs
    gemv_bw_eff: float = 0.70    # achievable fraction of DRAM BW for GEMV
    # PIM extension
    pim: bool = False
    pim_bw_gbs: float = 0.0      # in-memory bank bandwidth
    pim_tflops: float = 0.0      # PIM share of compute (excl. SoC)
    pim_intensity_cutoff: float = 4.0   # FLOP/byte below which ops go to PIM
    # collective fabric (multi-chip parts)
    chips: int = 1
    ici_gbs: float = 0.0         # per-link interconnect bandwidth
    hbm_gb: float = 0.0

    @property
    def total_tflops(self) -> float:
        return self.bf16_tflops + self.pim_tflops

    @property
    def ridge_flops_per_byte(self) -> float:
        return (self.bf16_tflops * 1e12) / (self.mem_bw_gbs * 1e9)


# ----- Table 1 (verbatim specs) --------------------------------------------

ORIN = Hardware("jetson-orin", mem_bw_gbs=203, bf16_tflops=100, hbm_gb=64)
THOR = Hardware("jetson-thor", mem_bw_gbs=273, bf16_tflops=500, hbm_gb=128)

ORIN_LPDDR5X = Hardware("orin+lpddr5x", mem_bw_gbs=273, bf16_tflops=100, hbm_gb=64)
ORIN_GDDR7 = Hardware("orin+gddr7", mem_bw_gbs=1000, bf16_tflops=100, hbm_gb=64)
ORIN_PIM = Hardware("orin+pim", mem_bw_gbs=546, bf16_tflops=100,
                    pim=True, pim_bw_gbs=2180, pim_tflops=1074 - 100, hbm_gb=64)
THOR_GDDR7 = Hardware("thor+gddr7", mem_bw_gbs=1000, bf16_tflops=500, hbm_gb=128)
THOR_PIM = Hardware("thor+pim", mem_bw_gbs=546, bf16_tflops=500,
                    pim=True, pim_bw_gbs=2180, pim_tflops=3993 - 500, hbm_gb=128)

# ----- TPU target (roofline constants used by repro.roofline) ---------------

TPU_V5E = Hardware("tpu-v5e", mem_bw_gbs=819, bf16_tflops=197,
                   gemm_eff=0.55, gemv_bw_eff=0.80,
                   chips=256, ici_gbs=50, hbm_gb=16)

CATALOG: Dict[str, Hardware] = {h.name: h for h in [
    ORIN, THOR, ORIN_LPDDR5X, ORIN_GDDR7, ORIN_PIM, THOR_GDDR7, THOR_PIM,
    TPU_V5E,
]}

TABLE1 = ["jetson-orin", "jetson-thor", "orin+lpddr5x", "orin+gddr7",
          "orin+pim", "thor+gddr7", "thor+pim"]


def get_hardware(name: str) -> Hardware:
    return CATALOG[name]
