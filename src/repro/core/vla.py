"""Executable phase-decomposed VLA pipeline (the runnable counterpart of the
paper's Figure 1 and of xpu_sim's analytical phases).

``vla_control_step`` runs: vision encode -> generation prefill -> CoT decode
-> action generation (discrete tokens or DiT), returning the action output
plus per-phase diagnostics. Each phase is a separately-jittable function so
the serving layer (and profilers) can time them independently — the same
decomposition the paper applies with Nsight.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as M
from repro.models.layers import ModelOptions


@dataclass
class VLAOutput:
    cot_tokens: jax.Array           # [B, n_cot] reasoning trace
    action_tokens: Optional[jax.Array]   # [B, n_action] (discrete mode)
    trajectory: Optional[jax.Array]      # [B, horizon, action_dim] (dit)
    phase_tokens: Dict[str, int]


def _greedy(logits):
    return jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]


def decode_tokens(cfg: ModelConfig, opts: ModelOptions, params, first_token,
                  caches, start_index: int, n_steps: int):
    """Autoregressive greedy decode of n_steps tokens, device-resident
    (delegates to the shared fused loop the serving engine builds on).
    Returns (tokens [B, n_steps], last_token, caches)."""
    return M.decode_loop(cfg, opts, params, first_token, caches, start_index,
                         n_steps)


def vla_control_step(cfg: ModelConfig, opts: ModelOptions, params, batch,
                     key=None, max_seq: Optional[int] = None) -> VLAOutput:
    """One full control step for a VLA observation batch.

    batch: {'tokens': [B, n_prompt] instruction, 'patches': [B,T,e] image}.
    """
    B = batch["tokens"].shape[0]
    a = cfg.action
    n_vis = cfg.vision.num_tokens if cfg.vision else 0
    n_act = (a.num_action_tokens if a and a.mode == "discrete" else 0)
    prompt = n_vis + batch["tokens"].shape[1]
    total = prompt + cfg.n_cot_tokens + n_act + 1
    max_seq = max_seq or total

    # Phase 1+2: vision encode + generation prefill (joint lowering; the
    # vision tower is separable for profiling via M.prefill internals)
    logits, caches = M.prefill(cfg, opts, params, batch, max_seq)
    tok = _greedy(logits)

    # Phase 3: CoT reasoning decode
    cot, tok, caches = decode_tokens(cfg, opts, params, tok, caches,
                                     prompt, cfg.n_cot_tokens)

    # Phase 4: action generation
    action_tokens = trajectory = None
    if a is None or a.mode == "discrete":
        n = n_act or 24
        action_tokens, _, caches = decode_tokens(
            cfg, opts, params, tok, caches, prompt + cfg.n_cot_tokens, n)
    else:
        # condition the DiT head on the embedding of the last CoT state
        cond = jnp.take(params["embed"], tok[:, 0], axis=0)
        key = key if key is not None else jax.random.PRNGKey(0)
        trajectory = M.generate_actions_dit(cfg, params, cond, key)

    return VLAOutput(
        cot_tokens=cot, action_tokens=action_tokens, trajectory=trajectory,
        phase_tokens={"vision": n_vis, "prompt": prompt,
                      "cot": cfg.n_cot_tokens,
                      "action": n_act or (a.dit_steps if a else 0)})
