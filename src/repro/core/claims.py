"""Validation of the paper's published claims against our simulator
(DESIGN.md §8). Each check returns (ok, measured, expectation-string);
``validate_all`` is exercised by tests and the benchmark harness.
"""
from __future__ import annotations

from typing import Dict, List, Tuple

from repro.configs import get_config
from repro.core.hardware import ORIN, THOR, get_hardware
from repro.core.scaling import scaling_sweep
from repro.core.xpu_sim import simulate_vla


def claim_generation_dominates() -> Tuple[bool, float, str]:
    """(ii) generation phase ~= 75% of step latency."""
    r = simulate_vla(get_config("molmoact-7b"), ORIN)
    g = r.generation_fraction
    return 0.60 <= g <= 0.90, g, "generation fraction in [0.60, 0.90] (~0.75)"


def claim_thor_speedup() -> Tuple[bool, float, str]:
    """(iii) Thor has 5x compute but only ~1.4x e2e speedup."""
    cfg = get_config("molmoact-7b")
    s = simulate_vla(cfg, ORIN).e2e / simulate_vla(cfg, THOR).e2e
    return 1.2 <= s <= 2.0, s, "e2e speedup in [1.2, 2.0] (~1.4) despite 5x FLOPS"


def claim_decode_memory_bound() -> Tuple[bool, float, str]:
    """Generation decode is memory-bandwidth bound."""
    r = simulate_vla(get_config("molmoact-7b"), ORIN)
    decode = [p for p in r.phases if p.name == "generation_decode"][0]
    return decode.memory_fraction > 0.9, decode.memory_fraction, \
        "decode memory-time fraction > 0.9"


def claim_far_from_realtime() -> Tuple[bool, float, str]:
    """(i) latencies ~200-300x higher than 10 Hz real-time."""
    r = simulate_vla(get_config("molmoact-7b"), ORIN)
    ratio = r.e2e / 0.1
    return 100 <= ratio <= 1000, ratio, "off-realtime ratio in [100, 1000]"


def claim_bandwidth_helps_but_insufficient() -> Tuple[bool, float, str]:
    """Fig 3: GDDR7/PIM raise control frequency monotonically with BW, yet
    the 100B model stays below 10 Hz on every Table-1 system."""
    big = scaling_sweep((100e9,))[0]
    freqs = {}
    for name in ("jetson-orin", "orin+lpddr5x", "orin+gddr7", "orin+pim"):
        freqs[name] = simulate_vla(big, get_hardware(name)).control_freq_hz
    mono = (freqs["jetson-orin"] < freqs["orin+lpddr5x"]
            < freqs["orin+gddr7"] < freqs["orin+pim"])
    best = max(simulate_vla(big, get_hardware(n)).control_freq_hz
               for n in ("thor+pim", "orin+pim", "thor+gddr7"))
    return mono and best < 10.0, best, \
        "monotone freq with BW; best 100B config < 10 Hz"


ALL_CLAIMS = {
    "generation_dominates": claim_generation_dominates,
    "thor_speedup_~1.4x": claim_thor_speedup,
    "decode_memory_bound": claim_decode_memory_bound,
    "200-300x_off_realtime": claim_far_from_realtime,
    "bw_helps_but_insufficient": claim_bandwidth_helps_but_insufficient,
}


def validate_all() -> List[Dict]:
    out = []
    for name, fn in ALL_CLAIMS.items():
        ok, measured, expect = fn()
        out.append({"claim": name, "ok": ok, "measured": measured,
                    "expectation": expect})
    return out
