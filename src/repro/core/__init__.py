"""The paper's primary contribution: phase-decomposed VLA characterization —
workload IR, analytical XPU roofline simulator (Table-1 hardware catalog +
PIM), scaling projections, claim validation, and the runnable VLA pipeline.
"""
from repro.core import claims, hardware, scaling, workload, xpu_sim
from repro.core.hardware import CATALOG, TABLE1, get_hardware
from repro.core.vla import VLAOutput, vla_control_step
from repro.core.workload import build_vla_step
from repro.core.xpu_sim import StepReport, simulate_vla

__all__ = ["CATALOG", "TABLE1", "StepReport", "VLAOutput", "build_vla_step",
           "claims", "get_hardware", "hardware", "scaling", "simulate_vla",
           "vla_control_step", "workload", "xpu_sim"]
