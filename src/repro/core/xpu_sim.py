"""Analytical XPU simulator (paper §3.2, reimplemented).

Each operator is priced with a two-term roofline:
    t = max(flops / (peak * eff_op), bytes / (bw * eff_bw))
with the PIM extension routing low-intensity operators to in-memory compute.

Cross-operator prefetch (paper: "early movement of operands through the
memory hierarchy to minimize stalls"): within a phase, weight streaming for
op i+1 overlaps compute of op i, so the phase lower-bounds at
    max(sum(t_compute), sum(t_memory))
instead of sum(max(...)). Both are reported; `prefetch=True` is the default
(and is what the paper's simulator models).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

from repro.configs.base import ModelConfig
from repro.core.hardware import Hardware
from repro.core.workload import Op, Phase, build_vla_step


@dataclass
class OpTime:
    op: Op
    t_compute: float
    t_memory: float
    on_pim: bool

    @property
    def t(self) -> float:
        return max(self.t_compute, self.t_memory)

    @property
    def bound(self) -> str:
        return "memory" if self.t_memory >= self.t_compute else "compute"


@dataclass
class PhaseReport:
    name: str
    op_times: List[OpTime]
    repeat: int = 1

    @property
    def t_per_op(self) -> float:
        return self.repeat * sum(o.t for o in self.op_times)

    @property
    def t_prefetch(self) -> float:
        c = sum(o.t_compute for o in self.op_times)
        m = sum(o.t_memory for o in self.op_times)
        return self.repeat * max(c, m)

    def time(self, prefetch: bool = True) -> float:
        return self.t_prefetch if prefetch else self.t_per_op

    @property
    def bound(self) -> str:
        c = sum(o.t_compute for o in self.op_times)
        m = sum(o.t_memory for o in self.op_times)
        return "memory" if m >= c else "compute"

    @property
    def memory_fraction(self) -> float:
        m = sum(o.t_memory for o in self.op_times)
        return m / max(m + sum(o.t_compute for o in self.op_times), 1e-30)


@dataclass
class StepReport:
    model: str
    hardware: str
    phases: List[PhaseReport]
    prefetch: bool = True

    @property
    def e2e(self) -> float:
        return sum(p.time(self.prefetch) for p in self.phases)

    @property
    def control_freq_hz(self) -> float:
        return 1.0 / max(self.e2e, 1e-30)

    def phase_seconds(self) -> Dict[str, float]:
        return {p.name: p.time(self.prefetch) for p in self.phases}

    def phase_fractions(self) -> Dict[str, float]:
        e = self.e2e
        return {p.name: p.time(self.prefetch) / e for p in self.phases}

    @property
    def generation_fraction(self) -> float:
        """The paper's 'generation phase' = prefill + CoT decode."""
        f = self.phase_fractions()
        return f.get("generation_prefill", 0) + f.get("generation_decode", 0)


def op_time(op: Op, hw: Hardware) -> OpTime:
    on_pim = (hw.pim and op.kind in ("gemv", "attn")
              and op.intensity < hw.pim_intensity_cutoff)
    if on_pim:
        bw = hw.pim_bw_gbs * 1e9 * hw.gemv_bw_eff
        peak = hw.pim_tflops * 1e12
        eff = 1.0
    else:
        bw = hw.mem_bw_gbs * 1e9 * hw.gemv_bw_eff
        peak = hw.bf16_tflops * 1e12
        eff = hw.gemm_eff if op.kind in ("gemm", "attn") else hw.gemm_eff
    t_c = op.flops / max(peak * eff, 1.0)
    t_m = op.bytes / max(bw, 1.0)
    return OpTime(op, t_c, t_m, on_pim)


def simulate_phases(phases: List[Phase], hw: Hardware,
                    prefetch: bool = True) -> List[PhaseReport]:
    return [PhaseReport(p.name, [op_time(o, hw) for o in p.ops], p.repeat)
            for p in phases]


def simulate_vla(cfg: ModelConfig, hw: Hardware, B: int = 1,
                 prefetch: bool = True) -> StepReport:
    phases = build_vla_step(cfg, B)
    return StepReport(cfg.name, hw.name, simulate_phases(phases, hw, prefetch),
                      prefetch)


def speedup(cfg: ModelConfig, a: Hardware, b: Hardware) -> float:
    """e2e speedup of b over a."""
    return simulate_vla(cfg, a).e2e / simulate_vla(cfg, b).e2e
