"""jit'd public wrapper for the flash attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.configs.base import GLOBAL_WINDOW
from repro.kernels.flash_attention.flash_attention import flash_attention_kernel


@functools.partial(jax.jit, static_argnames=("window", "causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, window: int = GLOBAL_WINDOW,
                    causal: bool = True, bq: int = 128, bk: int = 128,
                    interpret: bool = False):
    """Flash attention with GQA + causal/sliding-window masking.

    q [B,S,N,h]; k,v [B,S,K,h] with N % K == 0. S must divide by the block
    sizes (the model layer guarantees 128-multiples for the assigned shapes).
    """
    return flash_attention_kernel(q, k, v, window=window, causal=causal,
                                  bq=bq, bk=bk, interpret=interpret)
