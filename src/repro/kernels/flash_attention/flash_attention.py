"""Flash attention Pallas TPU kernel (prefill / training).

Tiling: grid (B, N, Sq/bq, Sk/bk) with the KV-block dimension innermost and
sequential; VMEM scratch carries the online-softmax state (m, l, acc) across
KV blocks. Causal and sliding-window masks are applied per block, and blocks
that are *entirely* masked are skipped with pl.when — so the MXU only sees
the ~triangular (or banded) set of block pairs, matching the useful-FLOP
count rather than the naive S^2.

GQA is folded into the index maps: query head n reads KV head n // (N/K).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.configs.base import GLOBAL_WINDOW

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bq: int, bk: int, nk: int, window: int, causal: bool):
    iq = pl.program_id(2)
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = iq * bq
    k_start = ik * bk

    # block-level skip decision (static per grid cell shape, dynamic values)
    run = True
    if causal:
        run = (k_start <= q_start + bq - 1)
    if window != GLOBAL_WINDOW:
        run = jnp.logical_and(run, (q_start - (k_start + bk - 1)) < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)      # [bq, h]
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # [bk, h]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s *= 1.0 / np.sqrt(q.shape[-1])
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            mask &= qpos >= kpos
        if window != GLOBAL_WINDOW:
            mask &= (qpos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None]) * mask         # kill fully-masked rows
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(q, k, v, *, window: int = GLOBAL_WINDOW,
                           causal: bool = True, bq: int = 128, bk: int = 128,
                           interpret: bool = False):
    """q [B,S,N,h]; k,v [B,Sk,K,h] -> [B,S,N,h]."""
    B, S, N, h = q.shape
    Sk, K = k.shape[1], k.shape[2]
    G = N // K
    bq, bk = min(bq, S), min(bk, Sk)
    nq, nk = S // bq, Sk // bk
    grid = (B, N, nq, nk)

    kernel = functools.partial(_kernel, bq=bq, bk=bk, nk=nk,
                               window=window, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq, 1, h), lambda b, n, iq, ik: (b, iq, n, 0)),
            pl.BlockSpec((1, bk, 1, h), lambda b, n, iq, ik: (b, ik, n // G, 0)),
            pl.BlockSpec((1, bk, 1, h), lambda b, n, iq, ik: (b, ik, n // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, 1, h), lambda b, n, iq, ik: (b, iq, n, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),      # m
            pltpu.VMEM((bq,), jnp.float32),      # l
            pltpu.VMEM((bq, h), jnp.float32),    # acc
        ],
        interpret=interpret,
    )(q, k, v)
