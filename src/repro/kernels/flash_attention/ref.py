"""Pure-jnp oracle for the flash attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GLOBAL_WINDOW


def attention_ref(q, k, v, window: int = GLOBAL_WINDOW, causal: bool = True):
    """q [B,S,N,h]; k,v [B,S,K,h] (GQA). fp32 softmax, returns q.dtype."""
    B, S, N, h = q.shape
    K = k.shape[2]
    G = N // K
    qg = (q * (1.0 / np.sqrt(h))).reshape(B, S, K, G, h)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32)
    pos = jnp.arange(S)
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= pos[:, None] >= pos[None, :]
    if window != GLOBAL_WINDOW:
        mask &= (pos[:, None] - pos[None, :]) < window
    s = jnp.where(mask[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, S, N, h)
