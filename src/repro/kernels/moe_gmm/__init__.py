from repro.kernels.moe_gmm import ops, ref
from repro.kernels.moe_gmm.ops import grouped_mlp

__all__ = ["grouped_mlp", "ops", "ref"]
