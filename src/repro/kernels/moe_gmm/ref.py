"""Pure-jnp oracle for the grouped expert MLP."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _act(h, g, kind: str):
    if kind == "silu":
        return jax.nn.silu(g) * h
    if kind == "gelu":
        return jax.nn.gelu(g) * h
    return jax.nn.gelu(h)


def grouped_mlp_ref(xe, wi, wg, wo, act: str = "silu"):
    """xe [E,C,D]; wi/wg [E,D,F]; wo [E,F,D] -> [E,C,D]."""
    h = jnp.einsum("ecd,edf->ecf", xe, wi)
    g = jnp.einsum("ecd,edf->ecf", xe, wg)
    return jnp.einsum("ecf,efd->ecd", _act(h, g, act), wo)
