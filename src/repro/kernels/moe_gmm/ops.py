"""jit'd public wrapper: full grouped expert MLP (up+gate+act then down)."""
from __future__ import annotations

import functools

import jax

from repro.kernels.moe_gmm.moe_gmm import gmm_down, gmm_gated


@functools.partial(jax.jit, static_argnames=("act", "interpret"))
def grouped_mlp(xe, wi, wg, wo, act: str = "silu", *,
                interpret: bool = False):
    """xe [E,C,D]; wi/wg [E,D,F]; wo [E,F,D] -> [E,C,D]."""
    h = gmm_gated(xe, wi, wg, act=act, interpret=interpret)
    return gmm_down(h, wo, interpret=interpret)
