"""Grouped expert-MLP Pallas TPU kernel (MoE hot loop).

Two kernels:
  gmm_gated: h = act(x@wi, x@wg)   grid (E, C/bc, F/bf, D/bd), D innermost,
             two fp32 VMEM accumulators, activation fused on the last D step.
  gmm_down:  y = h@wo              grid (E, C/bc, D/bd, F/bf), F innermost.

Block shapes are MXU-aligned (128 where the dims allow); the expert (group)
dimension is the outermost grid axis so expert weights stream HBM->VMEM once
per (bc x bf) output tile — with expert parallelism over the 'model' mesh
axis, each core only iterates its local expert shard.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _gated_kernel(x_ref, wi_ref, wg_ref, h_ref, acc_h, acc_g, *,
                  nd: int, act: str):
    idd = pl.program_id(3)

    @pl.when(idd == 0)
    def _init():
        acc_h[...] = jnp.zeros_like(acc_h)
        acc_g[...] = jnp.zeros_like(acc_g)

    x = x_ref[0].astype(jnp.float32)        # [bc, bd]
    wi = wi_ref[0].astype(jnp.float32)      # [bd, bf]
    wg = wg_ref[0].astype(jnp.float32)
    acc_h[...] += jax.lax.dot_general(x, wi, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)
    acc_g[...] += jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())),
                                      preferred_element_type=jnp.float32)

    @pl.when(idd == nd - 1)
    def _finalize():
        h, g = acc_h[...], acc_g[...]
        if act == "silu":
            out = jax.nn.silu(g) * h
        elif act == "gelu":
            out = jax.nn.gelu(g) * h
        else:
            out = jax.nn.gelu(h)
        h_ref[0] = out.astype(h_ref.dtype)


def _down_kernel(h_ref, wo_ref, y_ref, acc, *, nf: int):
    iff = pl.program_id(3)

    @pl.when(iff == 0)
    def _init():
        acc[...] = jnp.zeros_like(acc)

    h = h_ref[0].astype(jnp.float32)        # [bc, bf]
    wo = wo_ref[0].astype(jnp.float32)      # [bf, bd]
    acc[...] += jax.lax.dot_general(h, wo, (((1,), (0,)), ((), ())),
                                    preferred_element_type=jnp.float32)

    @pl.when(iff == nf - 1)
    def _finalize():
        y_ref[0] = acc[...].astype(y_ref.dtype)


def _blk(n: int, b: int) -> int:
    b = min(b, n)
    while n % b:
        b -= 1
    return b


def gmm_gated(x, wi, wg, *, act: str = "silu", bc: int = 128, bf: int = 128,
              bd: int = 512, interpret: bool = False):
    """x [E,C,D]; wi/wg [E,D,F] -> act-fused h [E,C,F]."""
    E, C, D = x.shape
    F = wi.shape[-1]
    bc, bf, bd = _blk(C, bc), _blk(F, bf), _blk(D, bd)
    grid = (E, C // bc, F // bf, D // bd)
    kernel = functools.partial(_gated_kernel, nd=grid[3], act=act)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), lambda e, ic, jf, kd: (e, ic, kd)),
            pl.BlockSpec((1, bd, bf), lambda e, ic, jf, kd: (e, kd, jf)),
            pl.BlockSpec((1, bd, bf), lambda e, ic, jf, kd: (e, kd, jf)),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), lambda e, ic, jf, kd: (e, ic, jf)),
        out_shape=jax.ShapeDtypeStruct((E, C, F), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32),
                        pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, wi, wg)


def gmm_down(h, wo, *, bc: int = 128, bd: int = 128, bf: int = 512,
             interpret: bool = False):
    """h [E,C,F]; wo [E,F,D] -> [E,C,D]."""
    E, C, F = h.shape
    D = wo.shape[-1]
    bc, bd, bf = _blk(C, bc), _blk(D, bd), _blk(F, bf)
    grid = (E, C // bc, D // bd, F // bf)
    kernel = functools.partial(_down_kernel, nf=grid[3])
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bf), lambda e, ic, jd, kf: (e, ic, kf)),
            pl.BlockSpec((1, bf, bd), lambda e, ic, jd, kf: (e, kf, jd)),
        ],
        out_specs=pl.BlockSpec((1, bc, bd), lambda e, ic, jd, kf: (e, ic, jd)),
        out_shape=jax.ShapeDtypeStruct((E, C, D), h.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bd), jnp.float32)],
        interpret=interpret,
    )(h, wo)
