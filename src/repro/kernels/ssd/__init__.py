from repro.kernels.ssd import ops, ref
from repro.kernels.ssd.ops import ssd

__all__ = ["ops", "ref", "ssd"]
