"""Pure-jnp oracles for the SSD kernel: sequential recurrence (ground truth)
and the chunked formulation (what the kernel implements)."""
from repro.models.layers import ssd_chunked, ssd_scan_ref  # noqa: F401

__all__ = ["ssd_chunked", "ssd_scan_ref"]
