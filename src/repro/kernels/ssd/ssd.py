"""Mamba2 SSD (state-space duality) Pallas TPU kernel.

Grid (B, H, S/Q) with the chunk dimension innermost and sequential; the
[P, N] recurrent state lives in VMEM scratch and is carried across chunks —
the TPU-idiomatic replacement for the warp-level scan of the CUDA SSD
kernel. Per chunk it computes the quadratic intra-chunk term on the MXU
(two [Q,*] matmuls), plus the rank-1 inter-chunk correction, then updates
the state. Q=128 keeps every matmul MXU-aligned.

Inputs are per-head slices: x [B,S,H,P], dt [B,S,H] (post-softplus, fp32),
A_log [H], B_/C_ [B,S,N] (G=1). Outputs y [B,S,H,P] and final state
[B,H,P,N].
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, alog_ref, b_ref, c_ref, y_ref, state_ref,
            h_scr, *, Q: int, nc: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    x = x_ref[0, :, 0, :].astype(jnp.float32)          # [Q, P]
    dt = dt_ref[0, :, 0]                               # [Q] fp32
    a = -jnp.exp(alog_ref[0].astype(jnp.float32))      # scalar
    b = b_ref[0].astype(jnp.float32)                   # [Q, N]
    c = c_ref[0].astype(jnp.float32)                   # [Q, N]

    dA = dt * a                                        # [Q]
    cum = jnp.cumsum(dA)                               # [Q]
    seg_end = cum[-1]

    # intra-chunk: scores[s,t] = (c_s . b_t) * exp(cum_s - cum_t) for s>=t
    cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # [Q,Q]
    Lexp = cum[:, None] - cum[None, :]
    sl = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    tl = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(sl >= tl, jnp.exp(Lexp), 0.0)
    w = cb * L                                         # [Q,Q]
    xdt = x * dt[:, None]                              # [Q,P]
    y = jax.lax.dot_general(w, xdt, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)

    # inter-chunk: y += (c * exp(cum)) @ h^T
    h = h_scr[...]                                     # [P,N]
    c_scaled = c * jnp.exp(cum)[:, None]
    y += jax.lax.dot_general(c_scaled, h, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)

    # state update: h = exp(seg)*h + (x*dt*decay_to_end)^T @ b
    decay_to_end = jnp.exp(seg_end - cum)              # [Q]
    xw = x * (dt * decay_to_end)[:, None]              # [Q,P]
    h_new = h * jnp.exp(seg_end) + jax.lax.dot_general(
        xw, b, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    h_scr[...] = h_new

    @pl.when(ic == nc - 1)
    def _emit_state():
        state_ref[0, 0] = h_new


def ssd_kernel(x, dt, A_log, B_, C_, *, Q: int = 128,
               interpret: bool = False):
    """x [B,S,H,P]; dt [B,S,H] fp32; A_log [H]; B_/C_ [B,S,N].
    Returns (y [B,S,H,P], state [B,H,P,N])."""
    Bsz, S, H, P = x.shape
    N = B_.shape[-1]
    Q = min(Q, S)
    nc = S // Q
    grid = (Bsz, H, nc)

    kernel = functools.partial(_kernel, Q=Q, nc=nc)
    y, state = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, hh, ic: (b, ic, hh, 0)),
            pl.BlockSpec((1, Q, 1), lambda b, hh, ic: (b, ic, hh)),
            pl.BlockSpec((1,), lambda b, hh, ic: (hh,)),
            pl.BlockSpec((1, Q, N), lambda b, hh, ic: (b, ic, 0)),
            pl.BlockSpec((1, Q, N), lambda b, hh, ic: (b, ic, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, Q, 1, P), lambda b, hh, ic: (b, ic, hh, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, hh, ic: (b, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x.shape, x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A_log, B_, C_)
    return y, state
