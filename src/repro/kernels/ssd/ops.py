"""jit'd public wrapper for the SSD kernel (model-facing signature)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.ssd.ssd import ssd_kernel


@functools.partial(jax.jit, static_argnames=("Q", "interpret"))
def ssd(xs, dt, A_log, B_, C_, *, Q: int = 128, interpret: bool = False):
    """Model-facing SSD. xs [B,S,H,P]; dt [B,S,H]; B_/C_ [B,S,G,N] (G=1).
    Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    b = B_[:, :, 0]
    c = C_[:, :, 0]
    return ssd_kernel(xs, dt.astype(jnp.float32), A_log, b, c, Q=Q,
                      interpret=interpret)
