"""jit'd public wrappers for the chunk-prefill kernels (dense + paged)."""
from __future__ import annotations

import functools

import jax

from repro.configs.base import GLOBAL_WINDOW
from repro.kernels.chunk_prefill.chunk_prefill import (
    chunk_prefill_attention_kernel)
from repro.kernels.chunk_prefill.paged import (
    paged_chunk_prefill_attention_kernel)


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def chunk_prefill_attention(q, k_cache, v_cache, index, *,
                            window: int = GLOBAL_WINDOW, bk: int = 128,
                            interpret: bool = False):
    """Banded chunk-prefill attention. q [B,S,N,h]; cache view [B,L,K,h]
    (pre-slice L to the live band to bound key-axis work); index int32
    scalar or per-slot [B] vector of chunk start positions. Blocks past a
    chunk's live prefix never leave HBM (index-map remap) and never
    compute (pl.when)."""
    return chunk_prefill_attention_kernel(q, k_cache, v_cache, index,
                                          window=window, bk=bk,
                                          interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_chunk_prefill_attention(q, k_pages, v_pages, page_table, index, *,
                                  k_scales=None, v_scales=None,
                                  window: int = GLOBAL_WINDOW,
                                  interpret: bool = False):
    """Banded chunk-prefill attention against a paged KV pool — the page
    table is gathered in the BlockSpec index map (scalar prefetch), so no
    host-side pool gather is materialized. q [B,S,N,h]; pages
    [num_pages, page_size, K, h]; page_table [B, npg] (pre-slice npg to
    the live band); index scalar or [B]. For quantized pools pass the
    sibling scales [num_pages, K] f32."""
    return paged_chunk_prefill_attention_kernel(
        q, k_pages, v_pages, page_table, index, k_scales=k_scales,
        v_scales=v_scales, window=window, interpret=interpret)
