"""Banded chunk-prefill Pallas TPU kernel (dense cache view).

Prefill-with-cache attention for one chunk of ``S`` queries written at
positions ``index .. index+S-1`` against a live KV cache view. The layout
follows ``decode_attention``: the per-slot start positions arrive as a
scalar-prefetch operand, the KV-block grid dimension is innermost and
sequential, and online-softmax state lives in VMEM scratch across it.
Blocks with no unmasked lane for *any* chunk row — past the chunk's last
position, or entirely older than its sliding window — are skipped twice
over: the BlockSpec index map remaps them to block 0 (repeated index-map
outputs elide the HBM->VMEM DMA) and ``pl.when`` skips their compute. Key-
axis work therefore scales with the live prefix ``[0, index + S)``, not
with the cache's allocated ``max_seq`` — the banded-chunk-attention item
the serving stack's prefill paths route through (see ``layers.attention``
and docs/scheduler.md).

Bit-stability contract (shared with the jnp fallback
``layers.attention_chunk_banded``): the online-softmax update for a block
that is fully masked for a given query row is an *exact* no-op
(``corr == exp(0) == 1``, ``p == 0``), so the result for any query depends
only on the absolute key-block partition up to its own position — never on
how the prompt was chunked or how much trailing cache view the caller
passed in.

``_chunk_prefill_body`` is shared with the paged variant (``paged.py``);
the two kernels differ only in how a KV block is located (contiguous cache
rows vs a scalar-prefetched page-table gather), so the numerically
sensitive part lives in exactly one place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.configs.base import GLOBAL_WINDOW

NEG_INF = -1e30


def _chunk_block_live(index, S: int, k_start, bk: int, window: int):
    """Whether KV block [k_start, k_start+bk) has any unmasked lane for a
    chunk of S queries at positions index..index+S-1 (shared by kernel
    bodies and BlockSpec index maps). The causal bound uses the *youngest*
    query (index+S-1); the window bound uses the *oldest* (index) — a block
    too old even for it is too old for every row."""
    live = k_start <= index + (S - 1)
    if window != GLOBAL_WINDOW:
        live = jnp.logical_and(live, (index - (k_start + bk - 1)) < window)
    return live


def _chunk_prefill_body(index, ik, q_ref, k_ref, v_ref, o_ref,
                        m_scr, l_scr, acc_scr, *, bk: int, nk: int,
                        window: int, k_scale=None, v_scale=None):
    """One KV block of the banded chunk online-softmax update. ``index`` is
    this slot's chunk start position; ``ik`` the block's position in the
    logical sequence (covering key positions [ik*bk, (ik+1)*bk)). Query row
    r sits at absolute position index + r. Lanes past a row's position
    (stale cache rows, or out-of-bounds tail lanes of a non-aligned view)
    are masked before they can contribute, and V is zeroed on lanes dead
    for every row so NaN-padded OOB tails cannot poison the accumulator.

    ``k_scale``/``v_scale`` (optional f32 — a scalar per-(page, head)
    scale, or a [bk, 1] per-token column) dequantize an int8/fp8 KV block
    inside the VMEM tile (quantized paged pools)."""
    S = q_ref.shape[1]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ik * bk

    @pl.when(_chunk_block_live(index, S, k_start, bk, window))
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)      # [S, h]
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # [bk, h]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if k_scale is not None:
            k = k * k_scale
        if v_scale is not None:
            v = v * v_scale
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s *= 1.0 / np.sqrt(q.shape[-1])                # [S, bk]
        q_pos = index + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos <= q_pos
        if window != GLOBAL_WINDOW:
            mask &= (q_pos - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        # lanes dead for every row (past the youngest query) may be OOB
        # tail lanes — NaN-padded in interpret mode, undefined on TPU —
        # and 0 * NaN would poison the accumulator
        v = jnp.where((kpos[0, :] <= index + (S - 1))[:, None], v, 0.0)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None]) * mask
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _kernel(idx_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bk: int, nk: int, window: int):
    _chunk_prefill_body(idx_ref[pl.program_id(0)], pl.program_id(2),
                        q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                        bk=bk, nk=nk, window=window)


def chunk_prefill_attention_kernel(q, k_cache, v_cache, index, *,
                                   window: int = GLOBAL_WINDOW, bk: int = 128,
                                   interpret: bool = False):
    """q [B,S,N,h] (one prefill chunk, already written to the cache);
    k/v cache view [B,L,K,h] (the caller may pre-slice L to the banded
    live bound — see layers.attention); index: int32 scalar or per-slot [B]
    vector of chunk start positions. Returns [B,S,N,h].

    L need not divide by ``bk``: the grid covers ceil(L/bk) blocks and the
    tail block's out-of-bounds lanes carry key positions past every query,
    so the causal mask (and the V zeroing) silently discards them."""
    B, S, N, h = q.shape
    L, K = k_cache.shape[1], k_cache.shape[2]
    G = N // K
    bk = min(bk, L)
    nk = pl.cdiv(L, bk)
    grid = (B, N, nk)
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (B,))

    def kv_map(b, n, ik, idx_ref):
        # remap fully-dead blocks to block 0 so their DMA is elided
        # (repeated index-map outputs are not re-fetched); compute is
        # pl.when-skipped. GQA: query head n reads KV head n // G.
        live = _chunk_block_live(idx_ref[b], S, ik * bk, bk, window)
        return b, jnp.where(live, ik, 0), n // G, 0

    kernel = functools.partial(_kernel, bk=bk, nk=nk, window=window)
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, S, 1, h),
                             lambda b, n, ik, idx_ref: (b, 0, n, 0)),
                pl.BlockSpec((1, bk, 1, h), kv_map),
                pl.BlockSpec((1, bk, 1, h), kv_map),
            ],
            out_specs=pl.BlockSpec((1, S, 1, h),
                                   lambda b, n, ik, idx_ref: (b, 0, n, 0)),
            scratch_shapes=[
                pltpu.VMEM((S,), jnp.float32),
                pltpu.VMEM((S,), jnp.float32),
                pltpu.VMEM((S, h), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(idx, q, k_cache, v_cache)
