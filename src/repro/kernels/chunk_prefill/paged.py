"""Paged banded chunk-prefill Pallas TPU kernel (bf16 or int8/fp8 pages).

Same banded chunk attention as ``chunk_prefill.py`` — the online-softmax
body is literally shared (``_chunk_prefill_body``) — but the KV cache lives
in a shared page pool ``[num_pages, page_size, K, h]`` addressed through a
per-slot page table, exactly like the paged flash-decode kernel
(``decode_attention/paged.py``). The page table and the per-slot chunk
start positions arrive as scalar-prefetch operands, so the *index map
itself* gathers KV pages: grid cell ``(b, head, p)`` DMAs physical page
``page_table[b, p]`` from HBM — the paged cache view needs **no host-side
pool gather** (the pre-dispatcher serving path materialized the whole
``npg * page_size`` dense view per chunk). Pages past the chunk's live
prefix, or entirely older than its sliding window, are remapped to the
reserved null page 0 so their DMA is never issued, and their compute is
skipped by ``pl.when``.

Quantized pools (``k_scales``/``v_scales`` given) stream 1-byte codes plus
one f32 scale array per pool — ``[num_pages, K]`` per-(page, head) or
``[num_pages, page_size, K]`` per-token, dispatched on ndim — gathered
through the same page-table index map and dequantized inside the VMEM
tile, as in the paged decode kernel.

Partition caveat: this kernel blocks the key axis per *page* (one grid cell
per page — a BlockSpec gather cannot span non-contiguous pages), while the
dense chunk kernel blocks per ``bk``. The blockwise online softmax is only
bit-stable across dispatches that share one absolute partition, so engines
that compare paged-kernel streams against dense-kernel streams must run
with ``page_size == prefill_band`` (``ServingEngine`` enforces this for
chunked-prefill mode under ``use_pallas``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.configs.base import GLOBAL_WINDOW
from repro.kernels.chunk_prefill.chunk_prefill import (_chunk_block_live,
                                                       _chunk_prefill_body)


def _paged_kernel(pt_ref, idx_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, ps: int, npg: int, window: int):
    _chunk_prefill_body(idx_ref[pl.program_id(0)], pl.program_id(2),
                        q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                        bk=ps, nk=npg, window=window)


def _paged_quant_kernel(pt_ref, idx_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                        o_ref, m_scr, l_scr, acc_scr, *, ps: int, npg: int,
                        window: int):
    _chunk_prefill_body(idx_ref[pl.program_id(0)], pl.program_id(2),
                        q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                        bk=ps, nk=npg, window=window,
                        k_scale=ks_ref[0, 0], v_scale=vs_ref[0, 0])


def _paged_quant_tok_kernel(pt_ref, idx_ref, q_ref, k_ref, v_ref, ks_ref,
                            vs_ref, o_ref, m_scr, l_scr, acc_scr, *, ps: int,
                            npg: int, window: int):
    # per-token scales: one f32 per row of the page, broadcast over h as a
    # [ps, 1] column against the [ps, h] KV tile
    _chunk_prefill_body(idx_ref[pl.program_id(0)], pl.program_id(2),
                        q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                        bk=ps, nk=npg, window=window,
                        k_scale=ks_ref[0, :, 0][:, None],
                        v_scale=vs_ref[0, :, 0][:, None])


def paged_chunk_prefill_attention_kernel(q, k_pages, v_pages, page_table,
                                         index, *, k_scales=None,
                                         v_scales=None,
                                         window: int = GLOBAL_WINDOW,
                                         interpret: bool = False):
    """q [B,S,N,h] (one prefill chunk, already scattered into the pool);
    k/v pages [num_pages, page_size, K, h] (bf16/f32, or int8/fp8 codes
    when ``k_scales``/``v_scales`` f32 — ``[num_pages, K]`` per-(page,
    head) or ``[num_pages, page_size, K]`` per-token, dispatched on ndim —
    are given; pass both or neither); page_table [B, npg] int32 physical
    page ids (the caller
    may pre-slice npg to the banded live bound); index int32 scalar or
    per-slot [B] vector of chunk start positions. Returns [B,S,N,h]."""
    if (k_scales is None) != (v_scales is None):
        raise ValueError("pass both k_scales and v_scales, or neither")
    B, S, N, h = q.shape
    ps, K = k_pages.shape[1], k_pages.shape[2]
    npg = page_table.shape[1]
    G = N // K
    grid = (B, N, npg)
    pt = jnp.asarray(page_table, jnp.int32)
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (B,))

    def kv_map(b, n, ip, pt_ref, idx_ref):
        # gather through the page table; dead pages remap to the null page
        # so their distinct-page DMA is never issued
        live = _chunk_block_live(idx_ref[b], S, ip * ps, ps, window)
        return jnp.where(live, pt_ref[b, ip], 0), 0, n // G, 0

    def scale_map(b, n, ip, pt_ref, idx_ref):
        # per-(page, head) scale block, remapped in lockstep with kv_map
        live = _chunk_block_live(idx_ref[b], S, ip * ps, ps, window)
        return jnp.where(live, pt_ref[b, ip], 0), n // G

    def scale_map_tok(b, n, ip, pt_ref, idx_ref):
        # per-token scale block: the page's [ps] scale column for this head
        live = _chunk_block_live(idx_ref[b], S, ip * ps, ps, window)
        return jnp.where(live, pt_ref[b, ip], 0), 0, n // G

    q_spec = pl.BlockSpec((1, S, 1, h),
                          lambda b, n, ip, pt_ref, idx_ref: (b, 0, n, 0))
    in_specs = [q_spec,
                pl.BlockSpec((1, ps, 1, h), kv_map),
                pl.BlockSpec((1, ps, 1, h), kv_map)]
    operands = [q, k_pages, v_pages]
    if k_scales is None:
        kernel = functools.partial(_paged_kernel, ps=ps, npg=npg,
                                   window=window)
    elif k_scales.ndim == 3:
        kernel = functools.partial(_paged_quant_tok_kernel, ps=ps, npg=npg,
                                   window=window)
        in_specs += [pl.BlockSpec((1, ps, 1), scale_map_tok),
                     pl.BlockSpec((1, ps, 1), scale_map_tok)]
        operands += [jnp.asarray(k_scales, jnp.float32),
                     jnp.asarray(v_scales, jnp.float32)]
    else:
        kernel = functools.partial(_paged_quant_kernel, ps=ps, npg=npg,
                                   window=window)
        in_specs += [pl.BlockSpec((1, 1), scale_map),
                     pl.BlockSpec((1, 1), scale_map)]
        operands += [jnp.asarray(k_scales, jnp.float32),
                     jnp.asarray(v_scales, jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, S, 1, h),
                                   lambda b, n, ip, pt_ref, idx_ref:
                                   (b, 0, n, 0)),
            scratch_shapes=[
                pltpu.VMEM((S,), jnp.float32),
                pltpu.VMEM((S,), jnp.float32),
                pltpu.VMEM((S, h), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        interpret=interpret,
    )(pt, idx, *operands)
