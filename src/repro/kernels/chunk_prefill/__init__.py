from repro.kernels.chunk_prefill.ops import (chunk_prefill_attention,
                                             paged_chunk_prefill_attention)

__all__ = ["chunk_prefill_attention", "paged_chunk_prefill_attention"]
