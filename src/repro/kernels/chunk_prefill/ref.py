"""Pure-jnp oracles for the chunk-prefill kernels (dense view and paged).

The oracle is the *mathematical* definition — one dense masked softmax over
the whole cache view with absolute positions — so kernel-vs-oracle tests
check the banded online softmax against an independent formulation rather
than against another copy of the same blockwise arithmetic."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GLOBAL_WINDOW
from repro.kernels.decode_attention.ref import gather_dequant


def chunk_prefill_ref(q, k_cache, v_cache, index,
                      window: int = GLOBAL_WINDOW):
    """q [B,S,N,h]; cache view [B,L,K,h]; index scalar or per-slot [B]
    vector of chunk start positions (query row r of slot b sits at absolute
    position index[b] + r). Returns [B,S,N,h]."""
    B, S, N, h = q.shape
    L, K = k_cache.shape[1], k_cache.shape[2]
    G = N // K
    qg = (q * (1.0 / np.sqrt(h))).reshape(B, S, K, G, h)
    s = jnp.einsum("bskgh,btkh->bkgst", qg, k_cache).astype(jnp.float32)
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (B,))
    q_pos = idx[:, None] + jnp.arange(S)                    # [B, S]
    kpos = jnp.arange(L)
    mask = kpos[None, None] <= q_pos[..., None]             # [B, S, L]
    if window != GLOBAL_WINDOW:
        mask &= (q_pos[..., None] - kpos[None, None]) < window
    s = jnp.where(mask[:, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bkgsh", w, v_cache)
    return jnp.moveaxis(out, (1, 2), (2, 3)).reshape(B, S, N, h)


def paged_chunk_prefill_ref(q, k_pages, v_pages, page_table, index,
                            window: int = GLOBAL_WINDOW,
                            k_scales=None, v_scales=None):
    """Oracle for the paged kernel: gather the slot's pages (and, for
    quantized pools, their per-page-per-head scales) into the dense view,
    dequantize, then run the dense oracle. q [B,S,N,h]; pages
    [num_pages, page_size, K, h]; page_table [B, npg]; index scalar or
    [B]; scales [num_pages, K] f32 or None."""
    kd, vd = gather_dequant(k_pages, v_pages, page_table, k_scales, v_scales)
    return chunk_prefill_ref(q, kd, vd, index, window=window)
