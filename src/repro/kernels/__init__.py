"""Pallas TPU kernels for the compute hot-spots, each validated in
interpret mode against a pure-jnp oracle (ref.py):

- flash_attention: prefill/training attention (causal + sliding window, GQA)
- decode_attention: flash-decode over the KV cache (the paper's bottleneck),
  dense per-slot layout + paged variant (page-table gather, serving engine)
- chunk_prefill: banded chunk-prefill attention over a live cache view
  (serving prefill-with-cache; dense view + paged page-table-gather variant)
- ssd: Mamba2 chunked state-space-duality scan
- moe_gmm: grouped expert MLP (capacity-based MoE hot loop)
"""
from repro.kernels import (chunk_prefill, decode_attention, flash_attention,
                           moe_gmm, ssd)

__all__ = ["chunk_prefill", "decode_attention", "flash_attention", "moe_gmm",
           "ssd"]
