"""Flash-decode Pallas TPU kernel — the paper's action-generation bottleneck.

Single-token GQA attention against a long KV cache. This op is memory-bound
(intensity ~= 1 FLOP/byte « v5e ridge of 240), so the kernel is laid out for
*bandwidth*: the KV cache streams HBM->VMEM in (bk, h) tiles; all G query
heads of a KV group ride along each tile (one cache read serves G heads, the
GQA arithmetic-intensity win). Online softmax state lives in VMEM scratch
across the sequential KV-block grid dimension.

The valid length (current decode position) arrives as a scalar-prefetch
operand so fully-invalid KV blocks are skipped before their DMA is issued —
the same early-exit a paged decode kernel does on GPU, re-expressed for the
TPU's sequential grid.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.configs.base import GLOBAL_WINDOW

NEG_INF = -1e30


def _kernel(idx_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bk: int, nk: int, window: int):
    ik = pl.program_id(2)
    index = idx_ref[0]

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ik * bk
    run = k_start <= index
    if window != GLOBAL_WINDOW:
        run = jnp.logical_and(run, (index - (k_start + bk - 1)) < window)

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)      # [G, h]
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # [bk, h]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s *= 1.0 / np.sqrt(q.shape[-1])                # [G, bk]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos <= index
        if window != GLOBAL_WINDOW:
            mask &= (index - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None]) * mask
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def decode_attention_kernel(q, k_cache, v_cache, index, *,
                            window: int = GLOBAL_WINDOW, bk: int = 512,
                            interpret: bool = False):
    """q [B,N,h]; k/v cache [B,S,K,h]; index: int32 scalar (current position).
    Returns [B,N,h]."""
    B, N, h = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = N // K
    bk = min(bk, S)
    nk = S // bk
    grid = (B, K, nk)
    # view q as [B, G, K, h] so one grid cell covers a whole KV group
    qg = q.reshape(B, K, G, h).swapaxes(1, 2)
    idx = jnp.asarray(index, jnp.int32).reshape(1)

    kernel = functools.partial(_kernel, bk=bk, nk=nk, window=window)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, G, 1, h), lambda b, kh, ik, idx_ref: (b, 0, kh, 0)),
                pl.BlockSpec((1, bk, 1, h), lambda b, kh, ik, idx_ref: (b, ik, kh, 0)),
                pl.BlockSpec((1, bk, 1, h), lambda b, kh, ik, idx_ref: (b, ik, kh, 0)),
            ],
            out_specs=pl.BlockSpec((1, G, 1, h),
                                   lambda b, kh, ik, idx_ref: (b, 0, kh, 0)),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, h), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, G, K, h), q.dtype),
        interpret=interpret,
    )(idx, qg, k_cache, v_cache)
    # [B,G,K,h] -> head n = k*G + g
    return out.swapaxes(1, 2).reshape(B, N, h)
