"""Flash-decode Pallas TPU kernel — the paper's action-generation bottleneck.

Single-token GQA attention against a long KV cache. This op is memory-bound
(intensity ~= 1 FLOP/byte « v5e ridge of 240), so the kernel is laid out for
*bandwidth*: the KV cache streams HBM->VMEM in (bk, h) tiles; all G query
heads of a KV group ride along each tile (one cache read serves G heads, the
GQA arithmetic-intensity win). Online softmax state lives in VMEM scratch
across the sequential KV-block grid dimension.

The valid length (current decode position) arrives as a scalar-prefetch
operand, so fully-invalid KV blocks are skipped twice over: the BlockSpec
index map remaps them to block 0 (repeated index-map outputs elide the
HBM->VMEM DMA) and ``pl.when`` skips their compute — the same early-exit a
paged decode kernel does on GPU, re-expressed for the TPU's sequential grid.
``index`` may be a scalar or a per-slot ``[B]`` vector (continuous
batching): each batch row masks and early-exits against its own position.

``_flash_decode_body`` is the single online-softmax body shared with the
paged variant (``paged.py``) — the two kernels differ only in how the KV
block for a grid cell is located (contiguous rows vs page-table gather), so
the numerically-sensitive part lives in exactly one place.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.configs.base import GLOBAL_WINDOW

NEG_INF = -1e30


def _block_live(index, k_start: int, bk: int, window: int):
    """Whether KV block [k_start, k_start+bk) has any unmasked position for
    a query at ``index`` (shared by kernel bodies and BlockSpec index maps)."""
    live = k_start <= index
    if window != GLOBAL_WINDOW:
        live = jnp.logical_and(live, (index - (k_start + bk - 1)) < window)
    return live


def _flash_decode_body(index, ik, q_ref, k_ref, v_ref, o_ref,
                       m_scr, l_scr, acc_scr, *, bk: int, nk: int,
                       window: int, k_scale=None, v_scale=None):
    """One KV block of the online-softmax flash-decode update. ``index`` is
    this row's current position; ``ik`` the block's position in the logical
    sequence (block covers positions [ik*bk, (ik+1)*bk)). Positions past
    ``index`` (including any out-of-bounds tail lanes of a non-aligned
    cache) are masked before they can contribute.

    ``k_scale``/``v_scale`` (optional f32 — a scalar per-(page, head)
    scale, or a [bk, 1] per-token column that broadcasts over the head
    dim) dequantize an int8/fp8 KV block inside the VMEM tile: the block's
    codes are multiplied by the scale right after the fp32 upcast, so HBM
    only ever streams 1-byte codes and the online softmax still runs in
    fp32."""
    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    k_start = ik * bk

    @pl.when(_block_live(index, k_start, bk, window))
    def _compute():
        q = q_ref[0, :, 0, :].astype(jnp.float32)      # [G, h]
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # [bk, h]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if k_scale is not None:
            k = k * k_scale
        if v_scale is not None:
            v = v * v_scale
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        s *= 1.0 / np.sqrt(q.shape[-1])                # [G, bk]
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = kpos <= index
        if window != GLOBAL_WINDOW:
            mask &= (index - kpos) < window
        s = jnp.where(mask, s, NEG_INF)
        # invalid lanes have p == 0 exactly, but v there may be garbage —
        # out-of-bounds tail lanes are NaN-padded in interpret mode and
        # undefined on TPU, and 0 * NaN would poison the accumulator
        v = jnp.where(mask[0, :, None], v, 0.0)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None]) * mask
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finalize():
        l = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, :, 0, :] = (acc_scr[...] / l[:, None]).astype(o_ref.dtype)


def _kernel(idx_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            bk: int, nk: int, window: int):
    _flash_decode_body(idx_ref[pl.program_id(0)], pl.program_id(2),
                       q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                       bk=bk, nk=nk, window=window)


def decode_attention_kernel(q, k_cache, v_cache, index, *,
                            window: int = GLOBAL_WINDOW, bk: int = 512,
                            interpret: bool = False):
    """q [B,N,h]; k/v cache [B,S,K,h]; index: int32 scalar or per-slot [B]
    vector of current positions (each must be < S). Returns [B,N,h].

    S need not divide by ``bk``: the grid covers ceil(S/bk) blocks and the
    tail block's out-of-bounds lanes carry positions > index, so the
    ``kpos <= index`` mask silently discards them — no KV positions are
    dropped and no padded copy of the cache is materialized.
    """
    B, N, h = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = N // K
    bk = min(bk, S)
    nk = pl.cdiv(S, bk)
    grid = (B, K, nk)
    # view q as [B, G, K, h] so one grid cell covers a whole KV group
    qg = q.reshape(B, K, G, h).swapaxes(1, 2)
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (B,))

    def kv_map(b, kh, ik, idx_ref):
        # remap fully-invalid blocks (past the position, or entirely older
        # than the window) to block 0 so their DMA is elided (repeated
        # index-map outputs are not re-fetched); compute is pl.when-skipped.
        live = _block_live(idx_ref[b], ik * bk, bk, window)
        return b, jnp.where(live, ik, 0), kh, 0

    kernel = functools.partial(_kernel, bk=bk, nk=nk, window=window)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, G, 1, h), lambda b, kh, ik, idx_ref: (b, 0, kh, 0)),
                pl.BlockSpec((1, bk, 1, h), kv_map),
                pl.BlockSpec((1, bk, 1, h), kv_map),
            ],
            out_specs=pl.BlockSpec((1, G, 1, h),
                                   lambda b, kh, ik, idx_ref: (b, 0, kh, 0)),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, h), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, G, K, h), q.dtype),
        interpret=interpret,
    )(idx, qg, k_cache, v_cache)
    # [B,G,K,h] -> head n = k*G + g
    return out.swapaxes(1, 2).reshape(B, N, h)
