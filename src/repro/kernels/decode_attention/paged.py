"""Paged flash-decode Pallas TPU kernel.

Same bandwidth-tuned single-token GQA attention as ``decode_attention`` —
the online-softmax body is literally shared (``_flash_decode_body``) — but
the KV cache lives in a shared page pool ``[num_pages, page_size, K, h]``
instead of a dense per-slot ``[B, S, K, h]`` buffer. Each slot's logical
sequence is described by a row of the page table: logical positions
``[p*page_size, (p+1)*page_size)`` live in physical page ``page_table[b, p]``.

The page table and the per-slot positions arrive as scalar-prefetch
operands, so the *index map itself* gathers KV blocks through the page
table: grid cell ``(b, kv_head, p)`` DMAs physical page ``page_table[b, p]``
from HBM. Fully-masked pages (past a slot's position, or entirely older
than its sliding window) are remapped to the null page so their DMA is
never issued, and their compute is skipped by ``pl.when`` — vLLM's paged
attention early-exit, re-expressed for the TPU's sequential grid.

Page 0 is the pool's reserved null page: padding entries in the table point
at it and its contribution is always masked.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.configs.base import GLOBAL_WINDOW
from repro.kernels.decode_attention.decode_attention import (_block_live,
                                                             _flash_decode_body)


def _paged_kernel(pt_ref, idx_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, ps: int, npg: int, window: int):
    _flash_decode_body(idx_ref[pl.program_id(0)], pl.program_id(2),
                       q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                       bk=ps, nk=npg, window=window)


def paged_decode_attention_kernel(q, k_pages, v_pages, page_table, index, *,
                                  window: int = GLOBAL_WINDOW,
                                  interpret: bool = False):
    """q [B,N,h]; k/v pages [num_pages, page_size, K, h]; page_table
    [B, npg] int32 physical page ids; index int32 scalar or per-slot [B]
    vector of current positions (< npg * page_size). Returns [B,N,h]."""
    B, N, h = q.shape
    ps, K = k_pages.shape[1], k_pages.shape[2]
    npg = page_table.shape[1]
    G = N // K
    grid = (B, K, npg)
    qg = q.reshape(B, K, G, h).swapaxes(1, 2)
    pt = jnp.asarray(page_table, jnp.int32)
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (B,))

    def kv_map(b, kh, ip, pt_ref, idx_ref):
        # KV blocks are gathered *through the page table*: grid cell
        # (b, kh, ip) streams physical page pt[b, ip]. Fully-masked pages
        # are remapped to the null page 0, so their distinct-page DMA is
        # never issued (repeated index-map outputs elide the fetch).
        live = _block_live(idx_ref[b], ip * ps, ps, window)
        return jnp.where(live, pt_ref[b, ip], 0), 0, kh, 0

    kernel = functools.partial(_paged_kernel, ps=ps, npg=npg, window=window)
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, G, 1, h),
                             lambda b, kh, ip, pt_ref, idx_ref: (b, 0, kh, 0)),
                pl.BlockSpec((1, ps, 1, h), kv_map),
                pl.BlockSpec((1, ps, 1, h), kv_map),
            ],
            out_specs=pl.BlockSpec((1, G, 1, h),
                                   lambda b, kh, ip, pt_ref, idx_ref:
                                   (b, 0, kh, 0)),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, h), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, G, K, h), q.dtype),
        interpret=interpret,
    )(pt, idx, qg, k_pages, v_pages)
    return out.swapaxes(1, 2).reshape(B, N, h)
