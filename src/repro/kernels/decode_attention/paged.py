"""Paged flash-decode Pallas TPU kernel (bf16 or quantized int8/fp8 pages).

Same bandwidth-tuned single-token GQA attention as ``decode_attention`` —
the online-softmax body is literally shared (``_flash_decode_body``) — but
the KV cache lives in a shared page pool ``[num_pages, page_size, K, h]``
instead of a dense per-slot ``[B, S, K, h]`` buffer. Each slot's logical
sequence is described by a row of the page table: logical positions
``[p*page_size, (p+1)*page_size)`` live in physical page ``page_table[b, p]``.

The page table and the per-slot positions arrive as scalar-prefetch
operands, so the *index map itself* gathers KV blocks through the page
table: grid cell ``(b, kv_head, p)`` DMAs physical page ``page_table[b, p]``
from HBM. Fully-masked pages (past a slot's position, or entirely older
than its sliding window) are remapped to the null page so their DMA is
never issued, and their compute is skipped by ``pl.when`` — vLLM's paged
attention early-exit, re-expressed for the TPU's sequential grid.

Quantized pools (``k_scales``/``v_scales`` given) stream 1-byte codes plus
one f32 scale array per pool — ``[num_pages, K]`` (per-(page, head)
granularity: a (1, 1) scale block per grid cell) or
``[num_pages, page_size, K]`` (per-token granularity: a (1, page_size, 1)
block whose per-row column broadcasts over the head dim) — gathered
through the same page-table index map, remapped in lockstep with the value
page. Dequantization — ``code * scale`` — happens inside the VMEM tile
right after the fp32 upcast, so HBM traffic per token drops to ~1 byte per
cache element while the online softmax stays fp32.

Page 0 is the pool's reserved null page: padding entries in the table point
at it and its contribution is always masked.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.configs.base import GLOBAL_WINDOW
from repro.kernels.decode_attention.decode_attention import (_block_live,
                                                             _flash_decode_body)


def _paged_kernel(pt_ref, idx_ref, q_ref, k_ref, v_ref, o_ref,
                  m_scr, l_scr, acc_scr, *, ps: int, npg: int, window: int):
    _flash_decode_body(idx_ref[pl.program_id(0)], pl.program_id(2),
                       q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                       bk=ps, nk=npg, window=window)


def _paged_quant_kernel(pt_ref, idx_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                        o_ref, m_scr, l_scr, acc_scr, *, ps: int, npg: int,
                        window: int):
    _flash_decode_body(idx_ref[pl.program_id(0)], pl.program_id(2),
                       q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                       bk=ps, nk=npg, window=window,
                       k_scale=ks_ref[0, 0], v_scale=vs_ref[0, 0])


def _paged_quant_tok_kernel(pt_ref, idx_ref, q_ref, k_ref, v_ref, ks_ref,
                            vs_ref, o_ref, m_scr, l_scr, acc_scr, *, ps: int,
                            npg: int, window: int):
    # per-token scales: one f32 per row of the page, broadcast over h as a
    # [ps, 1] column against the [ps, h] KV tile
    _flash_decode_body(idx_ref[pl.program_id(0)], pl.program_id(2),
                       q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr,
                       bk=ps, nk=npg, window=window,
                       k_scale=ks_ref[0, :, 0][:, None],
                       v_scale=vs_ref[0, :, 0][:, None])


def paged_decode_attention_kernel(q, k_pages, v_pages, page_table, index, *,
                                  k_scales=None, v_scales=None,
                                  window: int = GLOBAL_WINDOW,
                                  interpret: bool = False):
    """q [B,N,h]; k/v pages [num_pages, page_size, K, h] (bf16/f32, or
    int8/fp8 codes when ``k_scales``/``v_scales`` f32 — ``[num_pages, K]``
    per-(page, head) or ``[num_pages, page_size, K]`` per-token, dispatched
    on ndim — are given; pass both or neither); page_table [B, npg] int32
    physical page ids; index int32 scalar or per-slot [B] vector of current
    positions (< npg * page_size). Returns [B,N,h] in q's dtype."""
    if (k_scales is None) != (v_scales is None):
        raise ValueError("pass both k_scales and v_scales, or neither")
    B, N, h = q.shape
    ps, K = k_pages.shape[1], k_pages.shape[2]
    npg = page_table.shape[1]
    G = N // K
    grid = (B, K, npg)
    qg = q.reshape(B, K, G, h).swapaxes(1, 2)
    pt = jnp.asarray(page_table, jnp.int32)
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (B,))

    def kv_map(b, kh, ip, pt_ref, idx_ref):
        # KV blocks are gathered *through the page table*: grid cell
        # (b, kh, ip) streams physical page pt[b, ip]. Fully-masked pages
        # are remapped to the null page 0, so their distinct-page DMA is
        # never issued (repeated index-map outputs elide the fetch).
        live = _block_live(idx_ref[b], ip * ps, ps, window)
        return jnp.where(live, pt_ref[b, ip], 0), 0, kh, 0

    def scale_map(b, kh, ip, pt_ref, idx_ref):
        # per-(page, head) scale block, remapped in lockstep with kv_map so
        # a dead page's scale DMA is elided along with its value DMA
        live = _block_live(idx_ref[b], ip * ps, ps, window)
        return jnp.where(live, pt_ref[b, ip], 0), kh

    def scale_map_tok(b, kh, ip, pt_ref, idx_ref):
        # per-token scale block: the page's [ps] scale column for this head
        live = _block_live(idx_ref[b], ip * ps, ps, window)
        return jnp.where(live, pt_ref[b, ip], 0), 0, kh

    q_spec = pl.BlockSpec((1, G, 1, h),
                          lambda b, kh, ip, pt_ref, idx_ref: (b, 0, kh, 0))
    in_specs = [q_spec,
                pl.BlockSpec((1, ps, 1, h), kv_map),
                pl.BlockSpec((1, ps, 1, h), kv_map)]
    operands = [qg, k_pages, v_pages]
    if k_scales is None:
        kernel = functools.partial(_paged_kernel, ps=ps, npg=npg,
                                   window=window)
    elif k_scales.ndim == 3:
        kernel = functools.partial(_paged_quant_tok_kernel, ps=ps, npg=npg,
                                   window=window)
        in_specs += [pl.BlockSpec((1, ps, 1), scale_map_tok),
                     pl.BlockSpec((1, ps, 1), scale_map_tok)]
        operands += [jnp.asarray(k_scales, jnp.float32),
                     jnp.asarray(v_scales, jnp.float32)]
    else:
        kernel = functools.partial(_paged_quant_kernel, ps=ps, npg=npg,
                                   window=window)
        in_specs += [pl.BlockSpec((1, 1), scale_map),
                     pl.BlockSpec((1, 1), scale_map)]
        operands += [jnp.asarray(k_scales, jnp.float32),
                     jnp.asarray(v_scales, jnp.float32)]
    out = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=in_specs,
            out_specs=pl.BlockSpec((1, G, 1, h),
                                   lambda b, kh, ip, pt_ref, idx_ref:
                                   (b, 0, kh, 0)),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, h), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, G, K, h), q.dtype),
        interpret=interpret,
    )(pt, idx, *operands)
    return out.swapaxes(1, 2).reshape(B, N, h)
