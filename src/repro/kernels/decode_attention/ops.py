"""jit'd public wrapper for the decode attention kernel."""
from __future__ import annotations

import functools

import jax

from repro.configs.base import GLOBAL_WINDOW
from repro.kernels.decode_attention.decode_attention import (
    decode_attention_kernel)


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention(q, k_cache, v_cache, index, *,
                     window: int = GLOBAL_WINDOW, bk: int = 512,
                     interpret: bool = False):
    """Single-token flash-decode. q [B,N,h]; caches [B,S,K,h]; index scalar
    int32 position of the token being decoded. S must divide by bk."""
    return decode_attention_kernel(q, k_cache, v_cache, index, window=window,
                                   bk=bk, interpret=interpret)
