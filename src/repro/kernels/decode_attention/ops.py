"""jit'd public wrappers for the decode attention kernels (dense + paged)."""
from __future__ import annotations

import functools

import jax

from repro.configs.base import GLOBAL_WINDOW
from repro.kernels.decode_attention.decode_attention import (
    decode_attention_kernel)
from repro.kernels.decode_attention.paged import (
    paged_decode_attention_kernel)


@functools.partial(jax.jit, static_argnames=("window", "bk", "interpret"))
def decode_attention(q, k_cache, v_cache, index, *,
                     window: int = GLOBAL_WINDOW, bk: int = 512,
                     interpret: bool = False):
    """Single-token flash-decode. q [B,N,h]; caches [B,S,K,h]; index int32
    position of the token being decoded — scalar or per-slot [B] vector
    (continuous batching). S that does not divide by bk is handled by a
    ceil-divided grid whose out-of-bounds tail lanes are masked in-kernel
    (no padded copy of the cache is materialized)."""
    return decode_attention_kernel(q, k_cache, v_cache, index, window=window,
                                   bk=bk, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def paged_decode_attention(q, k_pages, v_pages, page_table, index, *,
                           k_scales=None, v_scales=None,
                           window: int = GLOBAL_WINDOW,
                           interpret: bool = False):
    """Single-token flash-decode against a paged KV pool. q [B,N,h]; pages
    [num_pages, page_size, K, h]; page_table [B, npg] int32; index scalar or
    per-slot [B] vector of current positions. For quantized (int8/fp8)
    pools pass the sibling per-page-per-head scales ``k_scales``/``v_scales``
    [num_pages, K] f32 — the kernel gathers them through the same page-table
    index map and dequantizes inside the VMEM tile."""
    return paged_decode_attention_kernel(q, k_pages, v_pages, page_table,
                                         index, k_scales=k_scales,
                                         v_scales=v_scales, window=window,
                                         interpret=interpret)
