"""Pure-jnp oracles for the decode attention kernels (dense and paged)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GLOBAL_WINDOW


def decode_attention_ref(q, k_cache, v_cache, index,
                         window: int = GLOBAL_WINDOW):
    """q [B,N,h]; caches [B,S,K,h]; index scalar or per-slot [B] vector.
    Returns [B,N,h]."""
    B, N, h = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = N // K
    qg = (q * (1.0 / np.sqrt(h))).reshape(B, K, G, h)
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache).astype(jnp.float32)
    kpos = jnp.arange(S)
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (B,))
    valid = kpos[None] <= idx[:, None]                      # [B, S]
    if window != GLOBAL_WINDOW:
        valid &= (idx[:, None] - kpos[None]) < window
    s = jnp.where(valid[:, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", w, v_cache)
    return out.reshape(B, N, h)


def gather_pages(pages, page_table):
    """Materialize the dense per-slot view of a paged cache.
    pages [num_pages, page_size, K, h]; page_table [B, npg] ->
    [B, npg*page_size, K, h] (logical position p*page_size + o at row p,
    offset o)."""
    B, npg = page_table.shape
    g = pages[page_table]                        # [B, npg, ps, K, h]
    return g.reshape(B, npg * pages.shape[1], *pages.shape[2:])


def gather_scales(scales, page_table, page_size: int):
    """Materialize dense per-position scales from pool scales.
    scales [num_pages, K] (per-(page, head)) or [num_pages, page_size, K]
    (per-token); page_table [B, npg] -> [B, npg*page_size, K, 1], the factor
    that dequantizes the matching ``gather_pages`` output (under "head"
    granularity every position of logical page p carries that page's
    scale; under "token" each position carries its own)."""
    g = scales[page_table]               # [B,npg,K] or [B,npg,ps,K]
    if scales.ndim == 3:
        B, npg = page_table.shape
        return g.reshape(B, npg * page_size, scales.shape[-1])[..., None]
    return jnp.repeat(g, page_size, axis=1)[..., None]


def gather_dequant(k_pages, v_pages, page_table, k_scales=None,
                   v_scales=None):
    """Materialize the dense per-slot K/V views of a paged pool,
    dequantizing (``code * scale`` in fp32) when per-page scales are given.
    The single definition of the gather(+dequant) prelude shared by the
    paged fallbacks (decode and banded chunk) and the paged oracles."""
    ps = k_pages.shape[1]
    kd = gather_pages(k_pages, page_table)
    vd = gather_pages(v_pages, page_table)
    if k_scales is not None:
        kd = kd.astype(jnp.float32) * gather_scales(k_scales, page_table, ps)
        vd = vd.astype(jnp.float32) * gather_scales(v_scales, page_table, ps)
    return kd, vd


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, index,
                               window: int = GLOBAL_WINDOW):
    """Oracle for the paged kernel: gather pages into the dense layout, then
    run the dense oracle. q [B,N,h]; pages [num_pages, page_size, K, h];
    page_table [B, npg]; index scalar or [B]."""
    return decode_attention_ref(q, gather_pages(k_pages, page_table),
                                gather_pages(v_pages, page_table),
                                index, window=window)


def paged_decode_attention_quant_ref(q, k_pages, v_pages, k_scales, v_scales,
                                     page_table, index,
                                     window: int = GLOBAL_WINDOW):
    """Oracle for the quantized paged kernel: gather the int8/fp8 pages AND
    their per-page-per-head scales through the page table, dequantize to
    fp32 (code * scale — the exact arithmetic the kernel does inside its
    VMEM tile), then run the dense oracle. q [B,N,h]; pages
    [num_pages, page_size, K, h] int8/fp8; scales [num_pages, K] or
    [num_pages, page_size, K] f32; page_table [B, npg]; index scalar or
    [B]."""
    ps = k_pages.shape[1]
    kd = gather_pages(k_pages, page_table).astype(jnp.float32) \
        * gather_scales(k_scales, page_table, ps)
    vd = gather_pages(v_pages, page_table).astype(jnp.float32) \
        * gather_scales(v_scales, page_table, ps)
    return decode_attention_ref(q, kd, vd, index, window=window)
