"""Pure-jnp oracle for the decode attention kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GLOBAL_WINDOW


def decode_attention_ref(q, k_cache, v_cache, index,
                         window: int = GLOBAL_WINDOW):
    """q [B,N,h]; caches [B,S,K,h]; index scalar. Returns [B,N,h]."""
    B, N, h = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = N // K
    qg = (q * (1.0 / np.sqrt(h))).reshape(B, K, G, h)
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache).astype(jnp.float32)
    kpos = jnp.arange(S)
    valid = kpos <= index
    if window != GLOBAL_WINDOW:
        valid &= (index - kpos) < window
    s = jnp.where(valid[None, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", w, v_cache)
    return out.reshape(B, N, h)
