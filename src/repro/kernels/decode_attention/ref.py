"""Pure-jnp oracles for the decode attention kernels (dense and paged)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GLOBAL_WINDOW


def decode_attention_ref(q, k_cache, v_cache, index,
                         window: int = GLOBAL_WINDOW):
    """q [B,N,h]; caches [B,S,K,h]; index scalar or per-slot [B] vector.
    Returns [B,N,h]."""
    B, N, h = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = N // K
    qg = (q * (1.0 / np.sqrt(h))).reshape(B, K, G, h)
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache).astype(jnp.float32)
    kpos = jnp.arange(S)
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (B,))
    valid = kpos[None] <= idx[:, None]                      # [B, S]
    if window != GLOBAL_WINDOW:
        valid &= (idx[:, None] - kpos[None]) < window
    s = jnp.where(valid[:, None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", w, v_cache)
    return out.reshape(B, N, h)


def gather_pages(pages, page_table):
    """Materialize the dense per-slot view of a paged cache.
    pages [num_pages, page_size, K, h]; page_table [B, npg] ->
    [B, npg*page_size, K, h] (logical position p*page_size + o at row p,
    offset o)."""
    B, npg = page_table.shape
    g = pages[page_table]                        # [B, npg, ps, K, h]
    return g.reshape(B, npg * pages.shape[1], *pages.shape[2:])


def paged_decode_attention_ref(q, k_pages, v_pages, page_table, index,
                               window: int = GLOBAL_WINDOW):
    """Oracle for the paged kernel: gather pages into the dense layout, then
    run the dense oracle. q [B,N,h]; pages [num_pages, page_size, K, h];
    page_table [B, npg]; index scalar or [B]."""
    return decode_attention_ref(q, gather_pages(k_pages, page_table),
                                gather_pages(v_pages, page_table),
                                index, window=window)
