"""Layer math: norms, RoPE, attention (dense / banded / flash-ref / decode),
MLP, MoE (capacity-based dispatch + small-batch gather path), Mamba2 SSD.

Everything is a pure function over a param dict produced by the templates in
``stacks.py``. Compute dtype follows the inputs; softmax/log-sum-exp run fp32.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GLOBAL_WINDOW, ModelConfig
from repro.distributed.sharding import constrain

NEG_INF = -1e30


@dataclass(frozen=True)
class ModelOptions:
    """Runtime knobs (orthogonal to the architecture config)."""
    dense_attn_threshold: int = 2048   # use plain masked attention below this
    attn_chunk: int = 512              # q/kv chunk for banded/flash-ref paths
    use_pallas: bool = False           # route hot ops through Pallas kernels
    pallas_interpret: bool = True      # CPU validation mode
    moe_capacity_factor: float = 1.25
    moe_per_seq_dispatch: bool = False  # per-sequence-local slot assignment
    #                                     (no cross-device prefix sums; §Perf)
    moe_gather_decode: bool = False    # tiny-batch decode: gather the top-k
    #                                    experts' weights instead of running
    #                                    the all-expert capacity path (§Perf)
    remat: bool = True                 # checkpoint scanned layer bodies
    remat_sublayers: bool = False      # nested per-sublayer remat: backward
    #                                    recomputes one sublayer at a time, so
    #                                    peak temp = max (not sum) over the
    #                                    block's sublayers (§Perf, Cell C)
    causal_pairs: bool = False         # triangular chunk-pair flash (perf opt)
    prefill_band: int = 32             # key-block size for banded prefill-
    #                                    with-cache attention: key-axis work
    #                                    per chunk covers the live prefix
    #                                    [0, cache_index + S) rounded up to
    #                                    this block, not max_seq. One stack-
    #                                    wide constant — the blockwise online
    #                                    softmax makes results independent of
    #                                    chunking/view length, but only for a
    #                                    fixed absolute block partition
    window_cache: bool = False         # per-layer-window KV cache (perf opt)
    unroll_layers: bool = False        # unroll the layer scan (cost-analysis
    #                                    validation: XLA counts scan bodies once)
    shard_axis: Optional[str] = None   # shard_map mesh axis the serving
    #                                    engine runs this trace under: the
    #                                    attention/MLP output projections
    #                                    psum their partial sums over it and
    #                                    the lm head all-gathers, but only
    #                                    for params whose local shape is
    #                                    actually sharded (replicated
    #                                    fallbacks stay collective-free)


# ---------------------------------------------------------------------------
# norms / rope / small pieces
# ---------------------------------------------------------------------------

def rms_norm(x, w, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    normed = (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
    return normed * w.astype(x.dtype)


def layer_norm(x, w, b, eps=1e-6):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    return (((xf - mu) * jax.lax.rsqrt(var + eps)).astype(x.dtype)
            * w.astype(x.dtype) + b.astype(x.dtype))


def apply_norm(p, x, cfg: ModelConfig, prefix: str):
    if cfg.norm == "layernorm":
        return layer_norm(x, p[prefix + "_w"], p[prefix + "_b"], cfg.norm_eps)
    return rms_norm(x, p[prefix + "_w"], cfg.norm_eps)


def rope(x, positions, theta: float):
    """Llama-style rotary embedding. x: [..., S, H, hd], positions: [..., S]."""
    hd = x.shape[-1]
    half = hd // 2
    freq = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    angles = positions[..., :, None].astype(jnp.float32) * freq  # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]   # [..., S, 1, half]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate([xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], -1)
    return out.astype(x.dtype)


def _act(h, g, kind: str):
    if kind == "silu":
        return jax.nn.silu(g) * h
    if kind == "gelu":
        return jax.nn.gelu(g) * h
    return jax.nn.gelu(h)      # gelu_plain (no gate)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

def qkv_proj(p, x, cfg_bias: bool):
    q = jnp.einsum("bsd,dnh->bsnh", x, p["wq"])
    k = jnp.einsum("bsd,dkh->bskh", x, p["wk"])
    v = jnp.einsum("bsd,dkh->bskh", x, p["wv"])
    if cfg_bias:
        q = q + p["bq"].astype(q.dtype)
        k = k + p["bk"].astype(k.dtype)
        v = v + p["bv"].astype(v.dtype)
    return q, k, v


def _grouped_scores(q, k):
    """q [B,Sq,N,h], k [B,Sk,K,h] -> logits [B,K,G,Sq,Sk]. Query head n uses
    KV head n // G (standard llama/HF GQA convention)."""
    B, Sq, N, h = q.shape
    K = k.shape[2]
    G = N // K
    qg = q.reshape(B, Sq, K, G, h)
    return jnp.einsum("bskgh,btkh->bkgst", qg, k)


def _grouped_out(w, v):
    """w [B,K,G,Sq,Sk], v [B,Sk,K,h] -> [B,Sq,N,h]."""
    B, K, G, Sq, _ = w.shape
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(B, Sq, K * G, v.shape[-1])


def attention_dense(q, k, v, q_pos, k_pos, window: int, causal: bool = True):
    """Plain masked attention. q [B,Sq,N,h]; k,v [B,Sk,K,h]."""
    scale = float(1.0 / np.sqrt(q.shape[-1]))
    logits = _grouped_scores(q * scale, k).astype(jnp.float32)
    mask = jnp.ones((q.shape[1], k.shape[1]), bool)
    if causal:
        mask &= q_pos[:, None] >= k_pos[None, :]
    if window != GLOBAL_WINDOW:
        mask &= (q_pos[:, None] - k_pos[None, :]) < window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return _grouped_out(w, v)


def attention_flash_ref(q, k, v, q_pos, k_pos, window: int, chunk: int,
                        causal_pairs: bool = False):
    """Memory-bounded attention: online softmax over KV chunks (pure jnp),
    scanned over q chunks so HLO size is O(1) in sequence length.

    Baseline scans every (q-chunk, kv-chunk) pair with masking (~2x causal
    FLOP overcount, like a naive flash schedule). ``causal_pairs=True``
    scans only the lower-triangular / in-window chunk pairs — the §Perf
    optimization that recovers the causal FLOP factor.
    """
    B, Sq, N, h = q.shape
    Sk = k.shape[1]
    K = k.shape[2]
    G = N // K
    nq, nk = Sq // chunk, Sk // chunk
    scale = float(1.0 / np.sqrt(h))
    qc = jnp.moveaxis((q * scale).reshape(B, nq, chunk, N, h), 1, 0)
    kc = k.reshape(B, nk, chunk, K, h)
    vc = v.reshape(B, nk, chunk, K, h)
    qpc = q_pos.reshape(nq, chunk)
    kpc = k_pos.reshape(nk, chunk)

    def pair(qi, kj, vj, m, l, acc, qp, kp):
        """One (q-chunk, kv-chunk) online-softmax update."""
        qg = qi.reshape(B, chunk, K, G, h)
        s = jnp.einsum("bskgh,btkh->bkgst", qg, kj).astype(jnp.float32)
        mask = qp[:, None] >= kp[None, :]
        if window != GLOBAL_WINDOW:
            mask &= (qp[:, None] - kp[None, :]) < window
        s = jnp.where(mask[None, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None]) * mask[None, None, None]
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("bkgst,btkh->bkgsh", p.astype(qi.dtype), vj)
        acc_new = acc * corr[..., None].astype(acc.dtype) + pv
        return m_new, l_new, acc_new

    def zeros_state(n_rows):
        return (jnp.full((B, K, G, n_rows), NEG_INF, jnp.float32),
                jnp.zeros((B, K, G, n_rows), jnp.float32),
                jnp.zeros((B, K, G, n_rows, h), q.dtype))

    if causal_pairs:
        # flattened triangular/banded list of (iq, jk) chunk pairs, scanned;
        # per-q-chunk softmax state lives in [nq, ...] buffers updated at iq.
        pairs = []
        for iq in range(nq):
            lo = 0
            if window != GLOBAL_WINDOW:
                lo = max(0, (iq * chunk - (window - 1)) // chunk)
            pairs += [(iq, jk) for jk in range(lo, min(iq + 1, nk))]
        iq_arr = jnp.asarray([p[0] for p in pairs], jnp.int32)
        jk_arr = jnp.asarray([p[1] for p in pairs], jnp.int32)
        m0, l0, acc0 = jax.tree.map(
            lambda z: jnp.stack([z] * nq), zeros_state(chunk))

        def body(carry, idx):
            m_all, l_all, acc_all = carry
            iq, jk = idx
            qi = jax.lax.dynamic_index_in_dim(qc, iq, 0, keepdims=False)
            kj = jax.lax.dynamic_index_in_dim(kc, jk, 1, keepdims=False)
            vj = jax.lax.dynamic_index_in_dim(vc, jk, 1, keepdims=False)
            st = jax.tree.map(
                lambda b: jax.lax.dynamic_index_in_dim(b, iq, 0, False),
                (m_all, l_all, acc_all))
            qp = jax.lax.dynamic_index_in_dim(qpc, iq, 0, False)
            kp = jax.lax.dynamic_index_in_dim(kpc, jk, 0, False)
            st = pair(qi, kj, vj, *st, qp, kp)
            out = jax.tree.map(
                lambda b, s: jax.lax.dynamic_update_index_in_dim(b, s, iq, 0),
                (m_all, l_all, acc_all), st)
            return out, None

        (m_all, l_all, acc_all), _ = jax.lax.scan(
            body, (m0, l0, acc0), (iq_arr, jk_arr))
        out = acc_all / jnp.maximum(l_all, 1e-30)[..., None].astype(acc_all.dtype)
        out = jnp.moveaxis(out, 0, 3)                  # [B,K,G,nq,chunk,h]
        out = out.reshape(B, K, G, Sq, h)
        return jnp.moveaxis(out, (1, 2), (2, 3)).reshape(B, Sq, N, h)

    def run_q_chunk(carry, xs):
        qi, qp = xs

        def body(st, jk):
            m, l, acc = st
            kj = jax.lax.dynamic_index_in_dim(kc, jk, 1, False)
            vj = jax.lax.dynamic_index_in_dim(vc, jk, 1, False)
            kp = jax.lax.dynamic_index_in_dim(kpc, jk, 0, False)
            m2, l2, a2 = pair(qi, kj, vj, m, l, acc, qp, kp)
            keep = kp.min() <= qp.max()
            if window != GLOBAL_WINDOW:
                keep &= (qp.min() - kp.max()) < window
            return jax.tree.map(lambda new, old: jnp.where(keep, new, old),
                                (m2, l2, a2), (m, l, acc)), None

        (m, l, acc), _ = jax.lax.scan(body, zeros_state(chunk),
                                      jnp.arange(nk))
        out = acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)
        return carry, out

    _, outs = jax.lax.scan(run_q_chunk, None, (qc, qpc))
    out = jnp.moveaxis(outs, 0, 3)                     # [B,K,G,nq,chunk,h]
    out = out.reshape(B, K, G, Sq, h)
    return jnp.moveaxis(out, (1, 2), (2, 3)).reshape(B, Sq, N, h)


def attention_banded(q, k, v, q_pos, k_pos, window: int, chunk: int):
    """Sliding-window attention with linear FLOPs: each q chunk attends to a
    fixed-size KV band gathered with dynamic_slice, scanned over q chunks."""
    B, Sq, N, h = q.shape
    K = k.shape[2]
    nq = Sq // chunk
    band = int(np.ceil(window / chunk) + 1) * chunk
    # left-pad KV so every band slice is in range
    pad = band - chunk
    kp = jnp.pad(k, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (pad, 0), (0, 0), (0, 0)))
    kpos_p = jnp.pad(k_pos, (pad, 0), constant_values=-10**9)

    def one(_, iq):
        start = iq * chunk  # band start in the padded buffer
        q_i = jax.lax.dynamic_slice_in_dim(q, start, chunk, 1)
        k_i = jax.lax.dynamic_slice_in_dim(kp, start, band, 1)
        v_i = jax.lax.dynamic_slice_in_dim(vp, start, band, 1)
        kp_i = jax.lax.dynamic_slice_in_dim(kpos_p, start, band, 0)
        qp_i = jax.lax.dynamic_slice_in_dim(q_pos, start, chunk, 0)
        return None, attention_dense(q_i, k_i, v_i, qp_i, kp_i, window)

    _, outs = jax.lax.scan(one, None, jnp.arange(nq))  # [nq,B,chunk,N,h]
    return jnp.moveaxis(outs, 0, 1).reshape(B, Sq, N, h)


def band_len(live: int, band: int, limit: int) -> int:
    """Static key-axis length for a banded prefill-with-cache dispatch: the
    live prefix ``live`` rounded up to a whole key block, clamped to the
    cache capacity ``limit``. The band bound is a pure FLOP/bytes
    optimization — trailing blocks are exact no-ops in the blockwise online
    softmax — so any bound >= the true live length is correct."""
    return min(-(-live // band) * band, limit)


def live_bound(live_len, limit: int) -> int:
    """Normalize the ``live_len`` argument of the chunk dispatch to a single
    static key-axis bound. ``None`` means the whole cache view; an int is a
    batch-wide bound; a tuple/list gives one static bound *per slot* and
    collapses to its max here — the shared band slice must cover the oldest
    slot, while the kernels' per-slot ``[B]`` index vectors already make
    every block past a younger slot's own position an exact no-op for that
    slot. The tuple form therefore buys the tightest *shared* slice plus
    per-slot key-lane accounting at the caller; note a jitted caller should
    pre-collapse to the max (a per-slot tuple as a static jit argument
    would retrace on every distinct batch age mix)."""
    if live_len is None:
        return limit
    if isinstance(live_len, (tuple, list)):
        return max(live_len) if live_len else limit
    return live_len


def attention_chunk_banded(q, k_cache, v_cache, index, window: int,
                           band: int):
    """Banded chunk-prefill core (pure jnp; the Pallas twin is
    ``kernels/chunk_prefill``): one prefill chunk of S queries at positions
    ``index .. index+S-1`` attends against a live cache view, scanned over
    fixed ``band``-sized key blocks with an online softmax.

    q [B,S,N,h]; cache view [B,L,K,h] (the caller slices L down to the
    banded live bound — see ``band_len``); index scalar or per-slot [B].

    The bit-stability contract the scheduler's equality gates build on: a
    key block that is fully masked for a query row updates that row's
    softmax state by *exactly* nothing (``corr == exp(0) == 1``, ``p == 0``
    — fp32-exact), so a query's result depends only on the absolute block
    partition of the keys at or before its own position. Chunking the
    prompt differently, or passing a longer (even stale/garbage-padded)
    cache view, changes only which blocks are no-ops — never the bits.
    """
    B, S, N, h = q.shape
    L, K = k_cache.shape[1], k_cache.shape[2]
    G = N // K
    Lp = -(-L // band) * band
    if Lp != L:                      # pad the view to whole blocks; padded
        pad = ((0, 0), (0, Lp - L), (0, 0), (0, 0))   # lanes sit past every
        k_cache = jnp.pad(k_cache, pad)               # query position and
        v_cache = jnp.pad(v_cache, pad)               # are masked exactly
    nk = Lp // band
    scale = float(1.0 / np.sqrt(h))
    qg = (q * scale).reshape(B, S, K, G, h)
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (B,))
    q_pos = idx[:, None] + jnp.arange(S, dtype=jnp.int32)     # [B, S]

    def block(st, jk):
        m, l, acc = st
        kj = jax.lax.dynamic_slice_in_dim(k_cache, jk * band, band, 1)
        vj = jax.lax.dynamic_slice_in_dim(v_cache, jk * band, band, 1)
        kpos = jk * band + jnp.arange(band)
        s = jnp.einsum("bskgh,btkh->bkgst", qg, kj).astype(jnp.float32)
        mask = kpos[None, None] <= q_pos[..., None]           # [B, S, band]
        if window != GLOBAL_WINDOW:
            mask &= (q_pos[..., None] - kpos[None, None]) < window
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None]) * mask[:, None, None]
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("bkgst,btkh->bkgsh", p,
                        vj.astype(jnp.float32))
        acc_new = acc * corr[..., None] + pv
        return (m_new, l_new, acc_new), None

    st0 = (jnp.full((B, K, G, S), NEG_INF, jnp.float32),
           jnp.zeros((B, K, G, S), jnp.float32),
           jnp.zeros((B, K, G, S, h), jnp.float32))
    (m, l, acc), _ = jax.lax.scan(block, st0, jnp.arange(nk))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return jnp.moveaxis(out, (1, 2), (2, 3)).reshape(B, S, N, h) \
        .astype(q.dtype)


def attention_decode(q, k_cache, v_cache, index, window: int,
                     opts: Optional[ModelOptions] = None):
    """Single-token decode against a cache. q [B,1,N,h]; cache [B,Smax,K,h];
    index = current position — scalar int32 or per-slot [B] vector
    (continuous batching). With ``opts.use_pallas`` the bandwidth-tuned
    flash-decode kernel handles both index forms; the einsum path below is
    the oracle."""
    if opts is not None and opts.use_pallas:
        from repro.kernels.decode_attention import ops as da_ops
        out = da_ops.decode_attention(q[:, 0], k_cache, v_cache, index,
                                      window=window,
                                      interpret=opts.pallas_interpret)
        return out[:, None]
    B, _, N, h = q.shape
    Smax, K = k_cache.shape[1], k_cache.shape[2]
    G = N // K
    scale = float(1.0 / np.sqrt(h))
    qg = (q * scale).reshape(B, K, G, h)
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache).astype(jnp.float32)
    kpos = jnp.arange(Smax)
    idx = jnp.broadcast_to(jnp.asarray(index), (B,))
    valid = kpos[None] <= idx[:, None]                      # [B, Smax]
    if window != GLOBAL_WINDOW:
        valid &= (idx[:, None] - kpos[None]) < window
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", w, v_cache)
    return out.reshape(B, 1, N, h)


def attention_decode_ring(q, k_cache, v_cache, index):
    """Decode against a ring-buffer KV cache of size == window (§Perf:
    window_cache). The ring holds exactly the last W positions, so the
    sliding-window mask is implicit; attention is permutation-invariant so
    slot order doesn't matter. Only slots not yet written (index < W) mask.
    """
    B, _, N, h = q.shape
    W, K = k_cache.shape[1], k_cache.shape[2]
    G = N // K
    scale = float(1.0 / np.sqrt(h))
    qg = (q * scale).reshape(B, K, G, h)
    s = jnp.einsum("bkgh,btkh->bkgt", qg, k_cache).astype(jnp.float32)
    slot = jnp.arange(W)
    idx = jnp.broadcast_to(jnp.asarray(index), (B,))
    valid = (slot[None] <= idx[:, None]) | (idx[:, None] >= W)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgt,btkh->bkgh", w, v_cache)
    return out.reshape(B, 1, N, h)


def update_cache(cache, new, index):
    """Write `new` [B,S,K,h] into `cache` [B,Smax,K,h] at position(s) `index`
    (scalar, or [B] per-slot vector for continuous batching)."""
    idx = jnp.asarray(index)
    if idx.ndim == 0:
        return jax.lax.dynamic_update_slice_in_dim(
            cache, new.astype(cache.dtype), idx, 1)
    return jax.vmap(
        lambda c, n, i: jax.lax.dynamic_update_slice_in_dim(
            c, n.astype(c.dtype), i, 0))(cache, new, idx)


def update_cache_chunk(cache, new, index, n_valid=None):
    """Write a prefill chunk ``new`` [B,C,K,h] into ``cache`` [B,Smax,K,h]
    at positions ``index .. index+C-1`` (``index`` scalar or [B]) via
    scatter rather than ``dynamic_update_slice``: rows at or past
    ``n_valid`` — the padding tail of a partial final chunk — get an
    out-of-bounds target index, which jax scatter *drops* (where a slice
    update would clamp the start and shift the whole write onto earlier,
    already-correct positions)."""
    B, C = new.shape[:2]
    smax = cache.shape[1]
    idx = (jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1, 1),
                            (B, 1))
           + jnp.arange(C, dtype=jnp.int32)[None])            # [B,C]
    if n_valid is not None:
        nv = jnp.broadcast_to(jnp.asarray(n_valid, jnp.int32).reshape(-1, 1),
                              (B, 1))
        idx = jnp.where(jnp.arange(C)[None] < nv, idx, smax)
    return cache.at[jnp.arange(B)[:, None], idx].set(new.astype(cache.dtype))


def update_cache_paged(pages, new, page_table, index, scales=None,
                       valid=None):
    """Write the decode token's KV into the page pool; quantize on write
    when the pool is quantized. Returns ``(pages, scales)`` (scales is None
    for unquantized pools).

    pages [num_pages, page_size, K, h]; new [B,1,K,h]; page_table [B,npg]
    int32; index scalar or per-slot [B] vector; scales ``[num_pages, K]``
    (per-(page, head) granularity) or ``[num_pages, page_size, K]``
    (per-token granularity) float32 — quantized pools only, dispatched on
    ``scales.ndim``. ``valid`` (scalar or [B] bool, default
    all-true) additionally routes masked rows to the null-page sink as
    zeros — the chunked-prefill path uses it for the padding rows of a
    partial final chunk. Logical position ``i`` of slot ``b``
    lives at (page_table[b, i // page_size], i % page_size). Distinct live
    slots always own distinct write pages, so the scatter has no cross-slot
    collisions (retired slots' table rows point at the reserved null page 0,
    a write sink that is never read unmasked).

    Quantized write (monotone amax policy, see models.kv_quant): the touched
    page's scale grows to cover the new token's amax; since one scale covers
    the whole (page, head), a grown scale requantizes the page's existing
    codes (dequant under the old scale -> insert the token -> encode under
    the new). ``encode(decode(c)) == c`` exactly at a fixed scale, so
    repeated writes at a stable scale are drift-free — and the common case
    (no slot's scale grew this step) therefore skips the page round-trip
    entirely via ``lax.cond``: it encodes just the token row under the
    existing scale, bit-identical to what the requantizing branch would
    produce. Per-token scales (``scales.ndim == 3``) have no cross-row
    coupling at all: the write replaces the row's codes *and* its scale,
    touching nothing else — which makes position re-writes exact (the
    property the speculative tick's rejected-row rollback relies on).
    Retired slots (table row all null page 0) keep the null page's
    documented all-zero state: their token codes and scale updates are
    masked to zero, so page 0 always dequantizes to exactly 0."""
    ps = pages.shape[1]
    B = new.shape[0]
    idx = jnp.broadcast_to(jnp.asarray(index, jnp.int32).reshape(-1), (B,))
    pid = jnp.take_along_axis(page_table, (idx // ps)[:, None], axis=1)[:, 0]
    if valid is not None:
        live = jnp.broadcast_to(jnp.asarray(valid, bool).reshape(-1), (B,))
        pid = jnp.where(live, pid, 0)
        new = jnp.where(live[:, None, None, None], new, 0)
    if scales is None:
        return pages.at[pid, idx % ps].set(new[:, 0].astype(pages.dtype)), None
    from repro.models import kv_quant
    tok = new[:, 0].astype(jnp.float32)                       # [B,K,h]
    sink = (pid == 0)                                         # retired slot
    if scales.ndim == 3:
        # per-token granularity: independent row write, scale replaced
        tok = jnp.where(sink[:, None, None], 0.0, tok)
        row_scale = jnp.max(jnp.abs(tok), -1) / kv_quant.qmax(pages.dtype)
        codes = kv_quant.encode(tok, row_scale[:, :, None], pages.dtype)
        return (pages.at[pid, idx % ps].set(codes),
                scales.at[pid, idx % ps].set(row_scale))
    old_scale = scales[pid]                                   # [B,K]
    tok_scale = jnp.max(jnp.abs(tok), -1) / kv_quant.qmax(pages.dtype)
    new_scale = jnp.where(sink[:, None], old_scale,
                          jnp.maximum(old_scale, tok_scale))  # monotone
    tok = jnp.where(sink[:, None, None], 0.0, tok)            # sink stays 0

    def rescale(pages, scales):
        # some page's range grew: dequant -> insert token -> requant
        page_f = kv_quant.decode(pages[pid], old_scale[:, None, :, None])
        page_f = jax.vmap(
            lambda pg, t, r: jax.lax.dynamic_update_slice_in_dim(
                pg, t[None], r, 0))(page_f, tok, idx % ps)    # [B,ps,K,h]
        codes = kv_quant.encode(page_f, new_scale[:, None, :, None],
                                pages.dtype)
        return pages.at[pid].set(codes), scales.at[pid].set(new_scale)

    def row_only(pages, scales):
        # every scale unchanged: single-row write, no page round-trip
        codes = kv_quant.encode(tok, old_scale[:, :, None], pages.dtype)
        return pages.at[pid, idx % ps].set(codes), scales

    return jax.lax.cond(jnp.any(new_scale > old_scale), rescale, row_only,
                        pages, scales)


def update_cache_paged_chunk(pages, new, page_table, start, n_valid=None,
                             scales=None):
    """Page-wise scatter of one prefill chunk: write ``new`` [B,C,K,h] into
    the pool at logical positions ``start .. start+C-1`` of each slot
    (``start`` scalar or [B]). Rows at or past ``n_valid`` (the padding tail
    of a partial final chunk) are routed to the null page as zeros, so a
    chunk is always a fixed ``C``-shaped dispatch regardless of how much of
    it is real prompt. Returns ``(pages, scales)`` like ``update_cache_paged``.

    Unquantized pools and per-token-scale quantized pools
    (``scales.ndim == 3``) take one vectorized scatter (distinct valid rows
    hit distinct (page, offset) cells — a slot owns its pages and positions
    are consecutive; per-token scales make every row's encode independent,
    bit-identical to the decode write path's row encode). Per-(page, head)
    quantized pools (``scales.ndim == 2``) replay the rows through the
    per-token monotone-amax write so chunked prefill shares the exact
    growth semantics (and drift characteristics) of the decode write
    path."""
    B, C = new.shape[:2]
    start = jnp.broadcast_to(jnp.asarray(start, jnp.int32).reshape(-1), (B,))
    nv = jnp.broadcast_to(
        jnp.asarray(C if n_valid is None else n_valid, jnp.int32).reshape(-1),
        (B,))
    ps = pages.shape[1]
    if scales is None or scales.ndim == 3:
        idx = start[:, None] + jnp.arange(C, dtype=jnp.int32)[None]   # [B,C]
        live = jnp.arange(C)[None] < nv[:, None]                      # [B,C]
        pid = jnp.take_along_axis(page_table, idx // ps, axis=1)
        pid = jnp.where(live, pid, 0)
        rows = jnp.where(live[..., None, None], new, 0)
        if scales is None:
            return pages.at[pid, idx % ps].set(rows.astype(pages.dtype)), None
        from repro.models import kv_quant
        rows = rows.astype(jnp.float32)
        row_scale = jnp.max(jnp.abs(rows), -1) / kv_quant.qmax(pages.dtype)
        codes = kv_quant.encode(rows, row_scale[..., None], pages.dtype)
        return (pages.at[pid, idx % ps].set(codes),
                scales.at[pid, idx % ps].set(row_scale))

    def body(i, carry):
        pages, scales = carry
        row = jax.lax.dynamic_slice_in_dim(new, i, 1, 1)              # [B,1]
        return update_cache_paged(pages, row, page_table, start + i,
                                  scales, valid=i < nv)

    return jax.lax.fori_loop(0, C, body, (pages, scales))


def attention_decode_paged(q, k_pages, v_pages, page_table, index,
                           window: int, opts: Optional[ModelOptions] = None,
                           k_scales=None, v_scales=None):
    """Single-token decode against a paged KV pool. q [B,1,N,h]; pages
    [num_pages, page_size, K, h]; page_table [B,npg]; index scalar or [B];
    k/v_scales [num_pages, K] or [num_pages, page_size, K] float32 for
    quantized pools (None otherwise).

    With ``opts.use_pallas`` the per-slot paged flash-decode kernel gathers
    KV blocks (and their scales) through the page table inside the kernel
    (scalar-prefetched index map) and dequantizes inside the VMEM tile. The
    fallback materializes the dense gather (dequantized, for quantized
    pools) and reuses ``attention_decode`` — bit-identical to the dense
    layout in the unquantized case, which is what the paged-vs-dense
    equivalence gates rely on."""
    if opts is not None and opts.use_pallas:
        from repro.kernels.decode_attention import ops as da_ops
        out = da_ops.paged_decode_attention(q[:, 0], k_pages, v_pages,
                                            page_table, index,
                                            k_scales=k_scales,
                                            v_scales=v_scales, window=window,
                                            interpret=opts.pallas_interpret)
        return out[:, None]
    from repro.kernels.decode_attention.ref import gather_dequant
    kd, vd = gather_dequant(k_pages, v_pages, page_table, k_scales, v_scales)
    return attention_decode(q, kd, vd, index, window)


# ---------------------------------------------------------------------------
# unified attention dispatch
# ---------------------------------------------------------------------------

def attention_route(mode: str, layout: str, *, S: int, Skv: int, window: int,
                    opts: ModelOptions, causal: bool = True) -> str:
    """The single routing decision for every attention dispatch:
    (mode × layout × shape) -> core name. ``attention()`` resolves its
    arguments to a (mode, layout) pair, asks this function for the core,
    and executes it via ``run_attention_core`` — there is no other
    attention if-ladder in the model stack. The full table is rendered in
    docs/architecture.md.

    Modes:
      - ``decode``  S == 1 against a cache (the paper's bottleneck phase)
      - ``chunk``   S > 1 prefill against a live cache view (chunked or
                    monolithic serving prefill; positioned or from zero)
      - ``fresh``   self-attention over exactly the new rows (training
                    forward, ring-buffer prefill, whole-buffer dryrun/cost
                    shapes — no earlier cache contents to see)
      - ``cross``   encoder context (never cached, never causal)

    Layouts: ``dense`` per-slot [B, Smax, K, h] buffers; ``paged`` shared
    page pools behind a per-slot table; ``ring`` per-layer-window ring
    buffers; ``none`` (no cache view).

    Shape gates: the fresh Pallas flash kernel keeps its
    ``S % 128 == 0 and Sq == Skv`` tiling gate, but chunk mode has no such
    restriction — the banded chunk kernel takes any (padded) chunk length
    against any cache view, which is how the old Pallas gate generalizes
    to padded bands."""
    if mode == "decode":
        if layout == "ring":
            return "decode_ring"
        if layout == "paged":
            return ("decode_paged_flash" if opts.use_pallas
                    else "decode_paged_gather")
        return "decode_flash" if opts.use_pallas else "decode_dense"
    if mode == "chunk":
        if layout == "paged":
            return ("chunk_paged_flash" if opts.use_pallas
                    else "chunk_banded_gather")
        return "chunk_flash" if opts.use_pallas else "chunk_banded"
    # fresh / cross: attention over exactly the new rows
    if opts.use_pallas and causal and S % 128 == 0 and Skv == S:
        return "fresh_flash"
    if Skv <= opts.dense_attn_threshold or Skv % opts.attn_chunk \
            or not causal:
        return "fresh_dense"
    if window != GLOBAL_WINDOW and window <= Skv // 2:
        return "fresh_banded"
    return "fresh_flash_ref"


def run_attention_core(route: str, q, k, v, *, opts: ModelOptions,
                       window: int, causal: bool = True, q_pos=None,
                       k_pos=None, index=None, page_table=None,
                       k_scales=None, v_scales=None, live_len=None):
    """Execute one routed attention core. ``k``/``v`` are the new rows
    (fresh/cross), the cache view [B, Smax, K, h] (dense decode/chunk), or
    the page pools [num_pages, page_size, K, h] (paged routes, with
    ``page_table`` and optional quantization ``*_scales``). ``index`` is
    the decode position / chunk start (scalar or per-slot [B]);
    ``live_len`` (static int, per-slot tuple of ints, or None) bounds the
    banded chunk cores' key axis to the live prefix — see ``band_len`` and
    ``live_bound``."""
    # -- decode: one token against the cache --------------------------------
    if route == "decode_ring":
        return attention_decode_ring(q, k, v, index)
    if route == "decode_flash":
        from repro.kernels.decode_attention import ops as da_ops
        out = da_ops.decode_attention(q[:, 0], k, v, index, window=window,
                                      interpret=opts.pallas_interpret)
        return out[:, None]
    if route == "decode_dense":
        return attention_decode(q, k, v, index, window)
    if route == "decode_paged_flash":
        from repro.kernels.decode_attention import ops as da_ops
        out = da_ops.paged_decode_attention(q[:, 0], k, v, page_table, index,
                                            k_scales=k_scales,
                                            v_scales=v_scales, window=window,
                                            interpret=opts.pallas_interpret)
        return out[:, None]
    if route == "decode_paged_gather":
        return attention_decode_paged(q, k, v, page_table, index, window,
                                      k_scales=k_scales, v_scales=v_scales)
    # -- chunk: S > 1 prefill against a live cache view ---------------------
    band = opts.prefill_band
    if route in ("chunk_flash", "chunk_banded"):
        smax = k.shape[1]
        Lb = band_len(live_bound(live_len, smax), band, smax)
        kb, vb = k[:, :Lb], v[:, :Lb]
        if route == "chunk_flash":
            from repro.kernels.chunk_prefill import ops as cp_ops
            return cp_ops.chunk_prefill_attention(
                q, kb, vb, index, window=window, bk=band,
                interpret=opts.pallas_interpret)
        return attention_chunk_banded(q, kb, vb, index, window, band)
    if route in ("chunk_paged_flash", "chunk_banded_gather"):
        ps, npg = k.shape[1], page_table.shape[1]
        Lb = band_len(live_bound(live_len, npg * ps), band, npg * ps)
        pt = page_table[:, :(Lb + ps - 1) // ps]
        if route == "chunk_paged_flash":
            from repro.kernels.chunk_prefill import ops as cp_ops
            return cp_ops.paged_chunk_prefill_attention(
                q, k, v, pt, index, k_scales=k_scales, v_scales=v_scales,
                window=window, interpret=opts.pallas_interpret)
        from repro.kernels.decode_attention.ref import gather_dequant
        kd, vd = gather_dequant(k, v, pt, k_scales, v_scales)
        return attention_chunk_banded(q, kd, vd, index, window, band)
    # -- fresh / cross: exactly the new rows --------------------------------
    if route in ("fresh_flash", "fresh_dense", "fresh_banded",
                 "fresh_flash_ref"):
        q_pos = q_pos[0] if q_pos.ndim == 2 else q_pos
        k_pos = k_pos[0] if k_pos.ndim == 2 else k_pos
        if route == "fresh_flash":
            from repro.kernels.flash_attention import ops as fa_ops
            return fa_ops.flash_attention(q, k, v, window=window,
                                          interpret=opts.pallas_interpret)
        if route == "fresh_dense":
            return attention_dense(q, k, v, q_pos, k_pos, window, causal)
        if route == "fresh_banded":
            return attention_banded(q, k, v, q_pos, k_pos, window,
                                    opts.attn_chunk)
        return attention_flash_ref(q, k, v, q_pos, k_pos, window,
                                   opts.attn_chunk,
                                   causal_pairs=opts.causal_pairs)
    raise ValueError(f"unknown attention route {route!r}")


def attention(p, x, cfg: ModelConfig, opts: ModelOptions, window: int,
              positions, cache=None, cache_index=None, ctx=None,
              ctx_prefix: str = "", causal: bool = True, page_table=None,
              n_valid=None, live_len=None):
    """Full attention sub-layer: projections + cache write path + the
    routed core (``attention_route`` / ``run_attention_core``) + output
    projection.

    Decode mode when ``cache`` is a (k,v) tuple and x has S==1.
    Cross-attention when ``ctx`` (encoder output) is given: K/V from ctx.
    With ``page_table`` [B,npg] the cache tuple is interpreted as paged
    pools [num_pages, page_size, K, h]; S>1 runs a prefill chunk that is
    scattered page-wise and attends through the pool.
    Prefill with a cache supports ``cache_index > 0`` (chunked prefill /
    prefill-from-position): the chunk is written at its positions and its
    queries attend against the live cache prefix through the banded chunk
    core, so earlier chunks — or prefix-cache pages the engine never
    recomputed — are visible, while key-axis work scales with
    ``live_len`` (a static bound on ``cache_index + S``; None means the
    whole view) instead of ``max_seq``. ``n_valid`` masks the padding tail
    of a partial final chunk out of the write path.
    Returns (out, new_cache).
    """
    pre = ctx_prefix
    B, S, D = x.shape
    q = jnp.einsum("bsd,dnh->bsnh", x, p[pre + "wq"])
    if cfg.qkv_bias:
        q = q + p[pre + "bq"].astype(q.dtype)
    if ctx is not None and pre:
        # cross-attention: cached encoder K/V precomputed by the caller
        k, v = ctx
    else:
        k = jnp.einsum("bsd,dkh->bskh", x, p[pre + "wk"])
        v = jnp.einsum("bsd,dkh->bskh", x, p[pre + "wv"])
        if cfg.qkv_bias:
            k = k + p[pre + "bk"].astype(k.dtype)
            v = v + p[pre + "bv"].astype(v.dtype)
    if cfg.pos == "rope" and not pre:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "act_seq", "act_heads", None)

    new_cache = cache
    if cache is not None and not pre:
        if page_table is not None:
            # paged layout: cache leaves are shared pools, positions resolve
            # through the per-slot page table; a 4-tuple cache carries
            # per-page quantization scales (see models.kv_quant)
            k_sc, v_sc = cache[2:] if len(cache) == 4 else (None, None)
            if S == 1:
                # n_valid (0 or 1 per slot) masks speculative draft writes
                # for dead slots / positions past the cache into the
                # null-page sink — take_along_axis would otherwise *clamp*
                # an out-of-range page lookup onto the slot's last page
                valid = (jnp.asarray(n_valid) > 0) if n_valid is not None \
                    else None
                k_cache, k_sc = update_cache_paged(cache[0], k, page_table,
                                                   cache_index, k_sc,
                                                   valid=valid)
                v_cache, v_sc = update_cache_paged(cache[1], v, page_table,
                                                   cache_index, v_sc,
                                                   valid=valid)
            else:   # prefill chunk: page-wise scatter at cache_index
                k_cache, k_sc = update_cache_paged_chunk(
                    cache[0], k, page_table, cache_index, n_valid, k_sc)
                v_cache, v_sc = update_cache_paged_chunk(
                    cache[1], v, page_table, cache_index, n_valid, v_sc)
            new_cache = (k_cache, v_cache)
            if k_sc is not None:
                new_cache += (k_sc, v_sc)
            route = attention_route("decode" if S == 1 else "chunk", "paged",
                                    S=S, Skv=k_cache.shape[1], window=window,
                                    opts=opts, causal=causal)
            out = run_attention_core(route, q, k_cache, v_cache, opts=opts,
                                     window=window, index=cache_index,
                                     page_table=page_table, k_scales=k_sc,
                                     v_scales=v_sc, live_len=live_len)
        else:
            smax = cache[0].shape[1]
            ring = (window != GLOBAL_WINDOW and smax == window)
            if not ring and S > smax:
                raise ValueError(f"prefill length {S} exceeds cache {smax}")
            if ring:
                k_cache = update_cache(cache[0], k, cache_index % smax)
                v_cache = update_cache(cache[1], v, cache_index % smax)
            else:
                k_cache = update_cache_chunk(cache[0], k, cache_index,
                                             n_valid)
                v_cache = update_cache_chunk(cache[1], v, cache_index,
                                             n_valid)
            new_cache = (k_cache, v_cache)
            whole = (not ring and isinstance(cache_index, int)
                     and cache_index == 0 and S == smax)
            if S == 1:
                mode, layout = "decode", ("ring" if ring else "dense")
            elif ring or whole:
                # ring caches don't support positioned prefill, and a chunk
                # filling the whole buffer has no earlier cache contents —
                # both attend within the fresh chunk (flash/banded cores,
                # which also tile big dryrun/cost shapes the untiled chunk
                # cores would not)
                mode, layout = "fresh", ("ring" if ring else "dense")
            else:
                mode, layout = "chunk", "dense"
            route = attention_route(mode, layout, S=S, Skv=S, window=window,
                                    opts=opts, causal=causal)
            if mode == "fresh":
                out = run_attention_core(route, q, k, v, opts=opts,
                                         window=window, causal=causal,
                                         q_pos=positions, k_pos=positions)
            else:
                out = run_attention_core(route, q, k_cache, v_cache,
                                         opts=opts, window=window,
                                         index=cache_index,
                                         live_len=live_len)
    elif pre and ctx is not None:
        route = attention_route("cross", "none", S=S, Skv=k.shape[1],
                                window=GLOBAL_WINDOW, opts=opts, causal=False)
        out = run_attention_core(route, q, k, v, opts=opts,
                                 window=GLOBAL_WINDOW, causal=False,
                                 q_pos=positions, k_pos=jnp.arange(k.shape[1]))
    else:
        route = attention_route("fresh", "none", S=S, Skv=k.shape[1],
                                window=window, opts=opts, causal=causal)
        out = run_attention_core(route, q, k, v, opts=opts, window=window,
                                 causal=causal, q_pos=positions,
                                 k_pos=positions)
    out = jnp.einsum("bsnh,nhd->bsd", out, p[pre + "wo"])
    if (opts.shard_axis is not None and not pre
            and p["wo"].shape[0] != cfg.num_heads):
        # head-sharded trace (shard_map): each shard computed its heads'
        # slice of the output projection, a partial sum over the full
        # d_model — the Megatron row-parallel reduction point
        out = jax.lax.psum(out, opts.shard_axis)
    return out, new_cache


# ---------------------------------------------------------------------------
# MLP / MoE
# ---------------------------------------------------------------------------

def mlp(p, x, cfg: ModelConfig, prefix: str = "",
        shard_axis: Optional[str] = None):
    h = jnp.einsum("bsd,df->bsf", x, p[prefix + "wi"])
    if cfg.act in ("silu", "gelu"):
        g = jnp.einsum("bsd,df->bsf", x, p[prefix + "wg"])
        h = _act(h, g, cfg.act)
    else:
        h = _act(h, None, cfg.act)
    h = constrain(h, "batch", "act_seq", "act_mlp")
    out = jnp.einsum("bsf,fd->bsd", h, p[prefix + "wo_mlp"])
    if shard_axis is not None and p[prefix + "wo_mlp"].shape[0] != cfg.d_ff:
        # f-sharded trace (shard_map): partial sum over the hidden width
        out = jax.lax.psum(out, shard_axis)
    return out


def moe(p, x, cfg: ModelConfig, opts: ModelOptions):
    """Capacity-based top-k MoE (GShard/MaxText-style sort-free dispatch).

    x [B,S,D] -> [B,S,D]. Expert matmuls are [E,C,D]x[E,D,F] batched einsums
    (the shape our Pallas moe_gmm kernel implements); dispatch/combine are
    scatter/gather built from an exclusive cumsum of expert assignments.

    Two slot-assignment modes:
    - global (default): cumsum over all T=B*S tokens. Exact GShard capacity
      semantics, but with batch sharded over 'data' the prefix sum crosses
      devices.
    - per-sequence (opts.moe_per_seq_dispatch, §Perf): slots are assigned
      within each sequence (capacity C_seq = ceil(S*K/E * factor)), so the
      cumsum is local to each batch shard — no cross-device prefix sums —
      at the cost of slightly more padding slots.
    """
    B, S, D = x.shape
    E, K = max(cfg.num_experts_padded, cfg.num_experts), cfg.top_k
    T = B * S
    xt = x.reshape(T, D)
    logits = jnp.einsum("td,de->te", xt, p["router"]).astype(jnp.float32)
    if E > cfg.num_experts:   # mask padded experts out of routing
        pad_mask = jnp.arange(E) >= cfg.num_experts
        logits = jnp.where(pad_mask[None], NEG_INF, logits)
    probs = jax.nn.softmax(logits, -1)
    gates, expert_idx = jax.lax.top_k(probs, K)          # [T,K]
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    if opts.moe_gather_decode and T * K <= E:
        # decode with T*K « E: stream only the hit experts' weights
        # (bytes ~ k/E of the capacity path, the memory-roofline optimum
        # for the paper's bottleneck phase on MoE decoders)
        idx = expert_idx.reshape(-1)                     # [T*K]
        wi = jnp.take(p["moe_wi"], idx, 0)               # [T*K, D, F]
        wg = jnp.take(p["moe_wg"], idx, 0)
        wo = jnp.take(p["moe_wo"], idx, 0)
        xk = jnp.repeat(xt, K, axis=0)                   # [T*K, D]
        h = jnp.einsum("td,tdf->tf", xk, wi)
        g = jnp.einsum("td,tdf->tf", xk, wg)
        he = jnp.einsum("tf,tfd->td", _act(h, g, cfg.act), wo)
        out = (he.reshape(T, K, D)
               * gates[..., None].astype(he.dtype)).sum(1)
        return out.reshape(B, S, D)

    E_real = cfg.num_experts   # capacity sizes from the REAL expert count
    if opts.moe_per_seq_dispatch and B > 1:
        Cs = max(1, int(np.ceil(K * S / E_real * opts.moe_capacity_factor)))
        C = B * Cs
        e_seq = expert_idx.reshape(B, S * K)             # [B, S*K]
        onehot = jax.nn.one_hot(e_seq, E, dtype=jnp.int32)
        pos = jnp.cumsum(onehot, axis=1) - onehot        # local prefix sum
        slot_s = jnp.take_along_axis(pos, e_seq[..., None], 2)[..., 0]
        keep = (slot_s < Cs).reshape(-1)
        # global slot: expert-major, then (sequence, within-seq slot)
        b_of = jnp.repeat(jnp.arange(B), S * K)
        slot = (b_of * Cs + slot_s.reshape(-1))
        flat_e = e_seq.reshape(-1)
        dest = jnp.where(keep, flat_e * C + slot, E * C)
    else:
        C = max(1, int(np.ceil(K * T / E_real * opts.moe_capacity_factor)))
        flat_e = expert_idx.reshape(-1)                  # [T*K]
        # position within expert (stable order over tokens; global cumsum)
        onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)
        pos_in_e = (jnp.cumsum(onehot, axis=0) - onehot)
        slot = jnp.take_along_axis(pos_in_e, flat_e[:, None], 1)[:, 0]
        keep = slot < C
        dest = jnp.where(keep, flat_e * C + slot, E * C)

    token_of = jnp.repeat(jnp.arange(T), K)
    buf_tokens = jnp.zeros((E * C + 1,), jnp.int32).at[dest].set(token_of)
    buf_valid = jnp.zeros((E * C + 1,), x.dtype).at[dest].set(1.0)
    buf_tokens, buf_valid = buf_tokens[:-1], buf_valid[:-1]

    xe = xt[buf_tokens].reshape(E, C, D) * buf_valid.reshape(E, C, 1)
    xe = constrain(xe, "act_experts", "batch", None)
    if opts.use_pallas:
        from repro.kernels.moe_gmm import ops as gmm_ops
        he = gmm_ops.grouped_mlp(xe, p["moe_wi"], p["moe_wg"], p["moe_wo"],
                                 cfg.act, interpret=opts.pallas_interpret)
    else:
        h = jnp.einsum("ecd,edf->ecf", xe, p["moe_wi"])
        g = jnp.einsum("ecd,edf->ecf", xe, p["moe_wg"])
        he = jnp.einsum("ecf,efd->ecd", _act(h, g, cfg.act), p["moe_wo"])
    he = he.reshape(E * C, D)

    # combine: each (token, k) reads its slot if kept
    src = jnp.where(keep, flat_e * C + slot, 0)
    picked = he[src] * keep[:, None].astype(he.dtype)    # [T*K, D]
    picked = picked.reshape(T, K, D) * gates[..., None].astype(he.dtype)
    out = picked.sum(1).reshape(B, S, D)
    return out


# ---------------------------------------------------------------------------
# Mamba2 (SSD)
# ---------------------------------------------------------------------------

def mamba_dims(cfg: ModelConfig):
    d_in = cfg.ssm_expand * cfg.d_model
    H = d_in // cfg.ssm_head_dim
    P = cfg.ssm_head_dim
    N = cfg.ssm_state
    G = 1
    conv_ch = d_in + 2 * G * N
    return d_in, H, P, N, G, conv_ch


def _conv1d_causal(x, w, b):
    """Depthwise causal conv. x [B,S,C], w [K,C], b [C]."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    return out + b


def ssd_scan_ref(xs, dt, A_log, B_, C_):
    """Sequential SSD recurrence (oracle; O(S) scan).
    xs [B,S,H,P], dt [B,S,H], A_log [H], B_/C_ [B,S,G,N] with G=1.
    h_t = exp(A dt_t) h_{t-1} + dt_t * B_t outer x_t ; y_t = C_t . h_t
    """
    Bsz, S, H, P = xs.shape
    N = B_.shape[-1]
    A = -jnp.exp(A_log.astype(jnp.float32))

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(A[None] * dt_t)                       # [B,H]
        db = dt_t[..., None] * b_t[:, 0][:, None, :]          # [B,H,N]
        h = h * decay[..., None, None] + x_t[..., None] * db[..., None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, c_t[:, 0])
        return h, y

    h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs_t = jnp.moveaxis(xs.astype(jnp.float32), 1, 0)
    dt_t = jnp.moveaxis(dt.astype(jnp.float32), 1, 0)
    b_t = jnp.moveaxis(B_.astype(jnp.float32), 1, 0)
    c_t = jnp.moveaxis(C_.astype(jnp.float32), 1, 0)
    hT, ys = jax.lax.scan(step, h0, (xs_t, dt_t, b_t, c_t))
    return jnp.moveaxis(ys, 0, 1).astype(xs.dtype), hT


def ssd_chunked(xs, dt, A_log, B_, C_, chunk: int = 128, h0=None,
                head_chunk: int = 16):
    """Chunked SSD (state-space duality, Mamba2 paper alg. 1-3):
    quadratic intra-chunk attention-like term + linear inter-chunk recurrence.
    Heads are processed in blocks of `head_chunk` via lax.map so the
    [B,nc,Q,Q,Hc] intra-chunk tensor stays VMEM/HBM-bounded at scale.
    Returns (y [B,S,H,P], final_state [B,H,P,N])."""
    Bsz, S, H, P = xs.shape
    if h0 is None and H > head_chunk and S > chunk:
        nh = H // head_chunk if H % head_chunk == 0 else 1
        if nh > 1:
            xs_h = jnp.moveaxis(
                xs.reshape(Bsz, S, nh, head_chunk, P), 2, 0)
            dt_h = jnp.moveaxis(
                dt.reshape(Bsz, S, nh, head_chunk), 2, 0)
            A_h = A_log.reshape(nh, head_chunk)
            y_h, st_h = jax.lax.map(
                lambda args: ssd_chunked(args[0], args[1], args[2], B_, C_,
                                         chunk=chunk, head_chunk=H),
                (xs_h, dt_h, A_h))
            y = jnp.moveaxis(y_h, 0, 2).reshape(Bsz, S, H, P)
            st = jnp.moveaxis(st_h, 0, 1).reshape(Bsz, H, P, N_ := st_h.shape[-1])
            return y, st
    G, N = B_.shape[2], B_.shape[3]
    Q = min(chunk, S)
    nc = S // Q
    A = -jnp.exp(A_log.astype(jnp.float32))                   # [H]
    xs_c = xs.reshape(Bsz, nc, Q, H, P)
    dt_c = dt.reshape(Bsz, nc, Q, H).astype(jnp.float32)
    b_c = B_.reshape(Bsz, nc, Q, G, N)[:, :, :, 0]
    c_c = C_.reshape(Bsz, nc, Q, G, N)[:, :, :, 0]

    dA = dt_c * A[None, None, None, :]                        # [B,nc,Q,H]
    cum = jnp.cumsum(dA, axis=2)                              # within-chunk
    seg_end = cum[:, :, -1]                                   # [B,nc,H]

    # --- intra-chunk (quadratic in Q) ---
    # L[s,t] = exp(cum_s - cum_t) for s >= t (decay from t to s)
    Lexp = cum[:, :, :, None, :] - cum[:, :, None, :, :]      # [B,nc,Q,Q,H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(Lexp), 0.0)
    cb = jnp.einsum("bcsn,bctn->bcst", c_c, b_c)              # [B,nc,Q,Q]
    w = cb[..., None] * L                                     # [B,nc,Q,Q,H]
    xdt = xs_c.astype(jnp.float32) * dt_c[..., None]          # [B,nc,Q,H,P]
    y_intra = jnp.einsum("bcsth,bcthp->bcshp", w, xdt)

    # --- chunk states ---
    decay_to_end = jnp.exp(seg_end[:, :, None] - cum)         # [B,nc,Q,H]
    states = jnp.einsum("bctn,bcth,bcthp->bchpn",
                        b_c, decay_to_end * dt_c, xs_c.astype(jnp.float32))

    # --- inter-chunk recurrence over nc chunks ---
    def step(h, inp):
        st, dec = inp                                         # dec [B,H]
        h_new = h * dec[..., None, None] + st
        return h_new, h                                       # emit state *before* chunk

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    st_t = jnp.moveaxis(states, 1, 0)
    dec_t = jnp.moveaxis(jnp.exp(seg_end), 1, 0)
    hT, h_prev = jax.lax.scan(step, h0, (st_t, dec_t))
    h_prev = jnp.moveaxis(h_prev, 0, 1)                       # [B,nc,H,P,N]

    # --- inter-chunk output ---
    decay_from_start = jnp.exp(cum)                           # [B,nc,Q,H]
    y_inter = jnp.einsum("bcsn,bcsh,bchpn->bcshp",
                         c_c, decay_from_start, h_prev)
    y = (y_intra + y_inter).reshape(Bsz, S, H, P).astype(xs.dtype)
    return y, hT


def mamba_block(p, x, cfg: ModelConfig, opts: ModelOptions,
                state=None, conv_state=None, decode: bool = False):
    """Mamba2 mixer. Returns (out, new_state, new_conv_state)."""
    d_in, H, P, N, G, conv_ch = mamba_dims(cfg)
    B, S, D = x.shape
    z = jnp.einsum("bsd,de->bse", x, p["w_z"])
    xBC = jnp.einsum("bsd,de->bse", x, p["w_xbc"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["w_dt"])
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32))

    if decode:
        # conv via cached last (K-1) inputs
        Kc = p["conv_w"].shape[0]
        window = jnp.concatenate([conv_state, xBC], axis=1)   # [B,Kc,convch]
        xBC_c = (window * p["conv_w"][None].astype(window.dtype)).sum(1, keepdims=True) \
            + p["conv_b"].astype(window.dtype)
        new_conv_state = window[:, 1:]
    else:
        xBC_c = _conv1d_causal(xBC, p["conv_w"], p["conv_b"])
        Kc = p["conv_w"].shape[0]
        new_conv_state = xBC[:, -(Kc - 1):] if S >= Kc - 1 else \
            jnp.pad(xBC, ((0, 0), (Kc - 1 - S, 0), (0, 0)))
    xBC_c = jax.nn.silu(xBC_c)
    xs, B_, C_ = jnp.split(xBC_c, [d_in, d_in + G * N], axis=-1)
    xs = xs.reshape(B, -1, H, P)
    B_ = B_.reshape(B, -1, G, N)
    C_ = C_.reshape(B, -1, G, N)

    if decode:
        A = -jnp.exp(p["A_log"].astype(jnp.float32))
        dt1 = dt[:, 0]                                        # [B,H]
        decay = jnp.exp(A[None] * dt1)
        db = dt1[..., None] * B_[:, 0, 0][:, None, :]
        h = state * decay[..., None, None] + \
            xs[:, 0].astype(jnp.float32)[..., None] * db[..., None, :]
        y = jnp.einsum("bhpn,bn->bhp", h, C_[:, 0, 0])[:, None]
        new_state = h
    else:
        if opts.use_pallas:
            from repro.kernels.ssd import ops as ssd_ops
            y, new_state = ssd_ops.ssd(xs, dt, p["A_log"], B_, C_,
                                       interpret=opts.pallas_interpret)
        else:
            y, new_state = ssd_chunked(xs, dt, p["A_log"], B_, C_)
    y = y.astype(x.dtype) + xs.astype(x.dtype) * p["d_skip"].astype(x.dtype)[None, None, :, None]
    y = y.reshape(B, -1, d_in)
    y = rms_norm(y, p["mamba_norm_w"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bse,ed->bsd", y, p["w_out"])
    return out, new_state, new_conv_state
