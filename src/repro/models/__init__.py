from repro.models.layers import ModelOptions
from repro.models.model import (decode_step, forward, init_caches,
                                init_params, model_template, prefill)
from repro.models.params import (init_params as init_from_template,
                                 param_count, param_shapes, param_shardings)

__all__ = ["ModelOptions", "decode_step", "forward", "init_caches",
           "init_params", "model_template", "param_count", "param_shapes",
           "param_shardings", "prefill"]
