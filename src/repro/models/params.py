"""Parameter templates: one source of truth for shape + init + logical axes.

``param_template(cfg)`` returns a pytree of ``PSpec``; from it we derive
``init_params`` (random init), ``param_shapes`` (ShapeDtypeStructs for AOT
lowering) and ``param_shardings`` (NamedShardings via the logical-axis rules).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import sharding_for


@dataclass(frozen=True)
class PSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"       # normal | zeros | ones | ssm_a | ssm_dt | pos
    fan_in: Optional[int] = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_pspec(x) -> bool:
    return isinstance(x, PSpec)


def _init_leaf(spec: PSpec, key, dtype):
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "ssm_a":
        # A_log init: A in [1, 16] -> log
        u = jax.random.uniform(key, spec.shape, jnp.float32, 1.0, 16.0)
        return jnp.log(u).astype(dtype)
    if spec.init == "ssm_dt":
        # dt bias such that softplus(dt) in [1e-3, 1e-1]
        u = jax.random.uniform(key, spec.shape, jnp.float32,
                               np.log(1e-3), np.log(1e-1))
        dt = jnp.exp(u)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(dtype)
    fan_in = spec.fan_in
    if fan_in is None:
        fan_in = spec.shape[0] if len(spec.shape) > 1 else spec.shape[-1]
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    if spec.init == "pos":
        scale = 0.02
    return (jax.random.normal(key, spec.shape, jnp.float32) * scale).astype(dtype)


def init_params(template, key, dtype=jnp.float32):
    leaves, treedef = jax.tree.flatten(template, is_leaf=is_pspec)
    keys = jax.random.split(key, len(leaves))
    return jax.tree.unflatten(
        treedef, [_init_leaf(l, k, dtype) for l, k in zip(leaves, keys)])


def param_shapes(template, dtype=jnp.bfloat16):
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, dtype), template,
        is_leaf=is_pspec)


def param_shardings(template, mesh):
    return jax.tree.map(
        lambda s: sharding_for(s.shape, s.axes, mesh), template,
        is_leaf=is_pspec)


def param_count(template) -> int:
    return sum(int(np.prod(l.shape))
               for l in jax.tree.leaves(template, is_leaf=is_pspec))


def stack(template, n: int, axis_name: Optional[str] = "layers"):
    """Prepend a stacked (scan) dimension to every leaf of a layer template."""
    return jax.tree.map(
        lambda s: dataclasses.replace(s, shape=(n,) + s.shape,
                                      axes=(axis_name,) + s.axes),
        template, is_leaf=is_pspec)
