"""Action heads (the paper's third subsystem, §2 'Action Transformer').

- discrete: action tokens live in the LM vocabulary; action generation is
  continued autoregressive decode (MolmoAct-style). No extra params.
- dit: a small Diffusion Transformer decodes a continuous [horizon, action_dim]
  trajectory conditioned (AdaLN) on the LM's final hidden state, iterating
  ``dit_steps`` denoising steps.
"""
from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ActionConfig
from repro.models.params import PSpec, stack


def dit_template(a: ActionConfig, d_lm: int) -> Dict:
    d, n = a.dit_d_model, a.dit_heads
    h = d // n
    layer = {
        "ada": PSpec((d, 6 * d), (None, None), "zeros"),      # AdaLN-zero
        "wq": PSpec((d, n, h), (None, "heads", "head_dim"), fan_in=d),
        "wk": PSpec((d, n, h), (None, "heads", "head_dim"), fan_in=d),
        "wv": PSpec((d, n, h), (None, "heads", "head_dim"), fan_in=d),
        "wo": PSpec((n, h, d), ("heads", "head_dim", None), fan_in=d),
        "wi": PSpec((d, 4 * d), (None, "mlp"), fan_in=d),
        "wo_mlp": PSpec((4 * d, d), ("mlp", None), fan_in=4 * d),
    }
    return {
        "in_proj": PSpec((a.action_dim, d), (None, None), fan_in=a.action_dim),
        "cond_proj": PSpec((d_lm, d), (None, None), fan_in=d_lm),
        "t_proj": PSpec((256, d), (None, None), fan_in=256),
        "pos": PSpec((a.horizon, d), (None, None), "pos"),
        "stack": stack(layer, a.dit_layers, "layers"),
        "final_ada": PSpec((d, 2 * d), (None, None), "zeros"),
        "out_proj": PSpec((d, a.action_dim), (None, None), "zeros"),
    }


def _timestep_embed(t, dim=256):
    half = dim // 2
    freqs = jnp.exp(-np.log(10_000.0) * jnp.arange(half) / half)
    ang = t.astype(jnp.float32)[..., None] * freqs
    return jnp.concatenate([jnp.cos(ang), jnp.sin(ang)], -1)


def _modulate(x, shift, scale):
    return x * (1 + scale[:, None]) + shift[:, None]


def dit_denoise(p, noisy, t, cond, a: ActionConfig):
    """One denoiser evaluation. noisy [B,horizon,action_dim], t [B],
    cond [B,d_lm] (LM final hidden). Returns predicted noise."""
    x = jnp.einsum("bha,ad->bhd", noisy, p["in_proj"]) + p["pos"][None]
    c = jnp.einsum("bd,de->be", cond, p["cond_proj"]) \
        + jnp.einsum("bt,td->bd", _timestep_embed(t), p["t_proj"])
    c = jax.nn.silu(c)
    n, h = a.dit_heads, a.dit_d_model // a.dit_heads

    def body(x, pl):
        mods = jnp.einsum("bd,de->be", c, pl["ada"]).reshape(
            x.shape[0], 6, a.dit_d_model)
        s1, g1, b1, s2, g2, b2 = [mods[:, i] for i in range(6)]
        y = _rms(x)
        y = _modulate(y, b1, s1)
        q = jnp.einsum("bhd,dne->bhne", y, pl["wq"])
        k = jnp.einsum("bhd,dne->bhne", y, pl["wk"])
        v = jnp.einsum("bhd,dne->bhne", y, pl["wv"])
        logits = jnp.einsum("bsne,btne->bnst", q, k) * float(1.0 / np.sqrt(h))
        w = jax.nn.softmax(logits.astype(jnp.float32), -1).astype(x.dtype)
        o = jnp.einsum("bnst,btne->bsne", w, v)
        x = x + g1[:, None] * jnp.einsum("bsne,ned->bsd", o, pl["wo"])
        y = _modulate(_rms(x), b2, s2)
        y = jax.nn.gelu(jnp.einsum("bhd,df->bhf", y, pl["wi"]))
        x = x + g2[:, None] * jnp.einsum("bhf,fd->bhd", y, pl["wo_mlp"])
        return x, None

    x, _ = jax.lax.scan(body, x, p["stack"])
    mods = jnp.einsum("bd,de->be", c, p["final_ada"]).reshape(
        x.shape[0], 2, a.dit_d_model)
    x = _modulate(_rms(x), mods[:, 1], mods[:, 0])
    return jnp.einsum("bhd,da->bha", x, p["out_proj"])


def _rms(x, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), -1, keepdims=True)
    return (x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(x.dtype)


def dit_generate(p, cond, a: ActionConfig, key):
    """DDIM-style deterministic sampling loop (dit_steps iterations)."""
    B = cond.shape[0]
    x = jax.random.normal(key, (B, a.horizon, a.action_dim), cond.dtype)
    ts = jnp.linspace(1.0, 1.0 / a.dit_steps, a.dit_steps)

    def step(x, t):
        eps = dit_denoise(p, x, jnp.full((B,), t * 1000.0), cond, a)
        x = x - eps * (1.0 / a.dit_steps)
        return x, None

    x, _ = jax.lax.scan(step, x, ts)
    return x
