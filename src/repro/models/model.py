"""Top-level model API, driven entirely by ModelConfig.

    template = model_template(cfg)            # PSpec tree (shapes+axes+init)
    params   = init_params(template, key)     # concrete weights
    logits   = forward(cfg, opts, params, batch)            # train / scoring
    logits, caches = prefill(cfg, opts, params, batch, max_seq)
    logits, caches = decode_step(cfg, opts, params, tok, caches, index)

``batch`` is a dict: tokens [B,S] (+ 'frames' [B,T,e] for audio enc-dec,
'patches' [B,T,e] for VLMs — the stubbed modality frontends).
"""
from __future__ import annotations

from typing import Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import action as action_lib
from repro.models import stacks
from repro.models.layers import ModelOptions, apply_norm
from repro.models.params import PSpec, init_params, param_shapes  # re-export
from repro.models.stacks import init_caches  # re-export

__all__ = ["model_template", "forward", "prefill", "prefill_chunk",
           "embed_prompt", "decode_step", "draft_step", "verify_chunk",
           "decode_loop", "encode_vision", "init_params", "init_caches",
           "ModelOptions"]


def model_template(cfg: ModelConfig) -> Dict:
    d = cfg.d_model
    t: Dict = {
        "embed": PSpec((cfg.vocab_size, d), ("vocab", "embed"), fan_in=d),
        "decoder": stacks.decoder_template(cfg),
    }
    t.update(stacks._norm_template(cfg, "final_norm", d))
    if not cfg.tie_embeddings:
        # stored [V, D] like the embedding so the logits einsum contracts on
        # D and GSPMD keeps the vocab dim model-sharded (see §Perf iter 1:
        # a [D, V] layout + transpose made XLA compute full-vocab logits
        # per device)
        t["lm_head"] = PSpec((cfg.vocab_size, d), ("vocab", "embed"), fan_in=d)
    if cfg.pos == "absolute":
        # sized for the largest assigned decode shape (decode_32k)
        t["pos"] = PSpec((32_768, d), (None, None), "pos")
    if cfg.encoder is not None:
        t["encoder"] = stacks.tower_template(cfg.encoder, d)
    if cfg.vision is not None:
        t["vision"] = stacks.tower_template(cfg.vision, d)
    if cfg.action is not None and cfg.action.mode == "dit":
        t["action_dit"] = action_lib.dit_template(cfg.action, d)
    return t


def _embed_tokens(params, tokens, cfg: ModelConfig, positions=None,
                  shard_axis=None):
    emb = params["embed"]
    if shard_axis is not None and emb.shape[0] != cfg.vocab_size:
        # vocab-sharded trace (shard_map): shard i holds embedding rows
        # [i*vl, (i+1)*vl). Look up the local slice with out-of-range ids
        # masked to row 0, zero the misses, and psum — exactly one shard
        # contributes each token's row
        vl = emb.shape[0]
        i = jax.lax.axis_index(shard_axis)
        loc = tokens - i * vl
        ok = (loc >= 0) & (loc < vl)
        x = jnp.take(emb, jnp.where(ok, loc, 0), axis=0)
        x = jax.lax.psum(jnp.where(ok[..., None], x, jnp.zeros_like(x)),
                         shard_axis)
    else:
        x = jnp.take(emb, tokens, axis=0)
    if cfg.pos == "absolute":
        pos = positions if positions is not None else jnp.arange(tokens.shape[-1])
        x = x + jnp.take(params["pos"], pos, axis=0).astype(x.dtype)
    return x


def _encode_context(params, batch, cfg: ModelConfig, opts: ModelOptions):
    """Run the stubbed-frontend towers. Returns (cross_ctx, prefix_embeds)."""
    ctx = prefix = None
    if cfg.encoder is not None:  # whisper: cross-attention context
        ctx = stacks.apply_tower(params["encoder"], batch["frames"],
                                 cfg.encoder, opts)
    if "prefix" in batch:        # precomputed vision prefix (see encode_vision)
        prefix = batch["prefix"]
    elif cfg.vision is not None:
        if "patches" not in batch:
            raise KeyError("vision model needs batch['patches'] "
                           "(or a precomputed batch['prefix'])")
        # VLM: prefix tokens in the LM sequence
        prefix = stacks.apply_tower(params["vision"], batch["patches"],
                                    cfg.vision, opts)
    return ctx, prefix


def encode_vision(cfg: ModelConfig, opts: ModelOptions, params, patches):
    """Vision tower alone: patches [B,T,e] -> prefix embeds [B,T,d_model].
    ``prefill``/``forward`` accept the result as ``batch['prefix']``, so the
    serving engine can time the vision phase separately from prefill (the
    paper's phase decomposition)."""
    assert cfg.vision is not None, "encode_vision requires a vision tower"
    return stacks.apply_tower(params["vision"], patches, cfg.vision, opts)


def _logits(params, x, cfg: ModelConfig, shard_axis=None):
    x = apply_norm(params, x, cfg, "final_norm")
    head = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,vd->bsv", x, head)   # head [V, D]
    if shard_axis is not None and head.shape[0] != cfg.vocab_size:
        # vocab-sharded trace (shard_map): the single all-gather of the
        # sharded serving program — local [B,S,V/n] logit slices tile back
        # to the full vocab right before sampling
        logits = jax.lax.all_gather(logits, shard_axis, axis=logits.ndim - 1,
                                    tiled=True)
    return constrain(logits, "batch", "act_seq", "act_vocab")


def _sequence(params, batch, cfg, opts):
    """Token embeddings for full-sequence passes (vision prefix folded in)."""
    tokens = batch["tokens"]
    ctx, prefix = _encode_context(params, batch, cfg, opts)
    if prefix is not None:
        n_vis = prefix.shape[1]
        text = _embed_tokens(params, tokens, cfg,
                             shard_axis=opts.shard_axis)
        x = jnp.concatenate([prefix.astype(text.dtype), text], axis=1)
        S = x.shape[1]
    else:
        x = _embed_tokens(params, tokens, cfg, shard_axis=opts.shard_axis)
        S = x.shape[1]
    positions = jnp.broadcast_to(jnp.arange(S), (x.shape[0], S))
    return x, positions, ctx


def forward(cfg: ModelConfig, opts: ModelOptions, params, batch,
            train: bool = False):
    """Full-sequence forward -> logits [B, S_total, V]."""
    x, positions, ctx = _sequence(params, batch, cfg, opts)
    x = constrain(x, "batch", "act_seq", "act_embed")
    x, _ = stacks.apply_decoder(params["decoder"], x, cfg, opts, positions,
                                ctx=ctx, train=train)
    return _logits(params, x, cfg, shard_axis=opts.shard_axis)


def prefill(cfg: ModelConfig, opts: ModelOptions, params, batch,
            max_seq: int, cache_dtype=jnp.bfloat16, caches=None,
            cache_index=0, page_table=None, live_len=None,
            fresh_caches=None):
    """Process the prompt, filling a decode cache sized ``max_seq``.
    Returns (last-position logits [B,1,V], caches).

    ``cache_index > 0`` is prefill-from-position: ``batch['tokens']`` is a
    *suffix* starting at that position, written into the supplied ``caches``
    and attending to everything already there — the contract chunked prefill
    and prefix-cache compute skip build on (a prefix hit prefills only the
    non-shared suffix). Positioned prefill is tokens-only (a vision prefix
    lives at positions 0..n_vis-1, which a suffix by definition starts
    after) and needs ``caches`` from an earlier prefill or ``init_caches``.
    ``page_table`` [B, npg] routes the writes/reads through a paged pool
    (see serving.kv_pool).

    ``live_len`` (static int) bounds the banded chunk attention core's key
    axis to the live cache prefix ``[0, live_len)``; prefill-from-zero
    derives it from the prompt shape, positioned prefill derives it from a
    static ``cache_index``, and callers with a dynamic ``cache_index``
    (the serving engine) pass the bound explicitly. ``None`` with a
    dynamic index falls back to the full ``max_seq`` view — correct, just
    unbanded."""
    positioned = caches is not None or page_table is not None \
        or not (isinstance(cache_index, int) and cache_index == 0)
    if not positioned:
        x, positions, ctx = _sequence(params, batch, cfg, opts)
        # fresh_caches substitutes for the internally-allocated zeros on
        # this prefill-from-zero path (caller-shaped, e.g. per-shard head
        # slices inside a shard_map trace, where init_caches would build
        # the global head count); it must be a zeroed dense cache tree and
        # does not flip the call into positioned mode
        caches = (fresh_caches if fresh_caches is not None else
                  init_caches(cfg, x.shape[0], max_seq, cache_dtype, opts))
        if live_len is None:
            live_len = x.shape[1]
    else:
        if caches is None:
            raise ValueError("prefill from cache_index > 0 (or through a "
                             "page table) needs existing caches")
        if cfg.encoder is not None or "prefix" in batch or "patches" in batch:
            raise ValueError("positioned prefill is tokens-only; fold the "
                             "vision prefix in at cache_index == 0 (or use "
                             "prefill_chunk over precomputed embeddings)")
        tokens = batch["tokens"]
        B, S = tokens.shape
        positions = jnp.broadcast_to(
            jnp.asarray(cache_index, jnp.int32) +
            jnp.arange(S, dtype=jnp.int32), (B, S))
        x = _embed_tokens(params, tokens, cfg, positions=positions,
                          shard_axis=opts.shard_axis)
        ctx = None
        if live_len is None and isinstance(cache_index, int):
            live_len = cache_index + S
    x, caches = stacks.apply_decoder(params["decoder"], x, cfg, opts,
                                     positions, caches=caches,
                                     cache_index=cache_index, ctx=ctx,
                                     page_table=page_table,
                                     live_len=live_len)
    return _logits(params, x[:, -1:], cfg,
                   shard_axis=opts.shard_axis), caches


def embed_prompt(cfg: ModelConfig, opts: ModelOptions, params, batch):
    """Embedding sequence for a prompt exactly as ``prefill`` would build it
    (vision prefix folded in, absolute position table applied). The chunked
    scheduler computes this once per request and slices it into fixed-size
    ``prefill_chunk`` calls. Encoder-decoder models are not sliceable this
    way (their cross-attention context is whole-sequence state)."""
    if cfg.encoder is not None:
        raise ValueError("chunked prefill does not support encoder-decoder "
                         "models (whole-sequence cross-attention context)")
    x, _, _ = _sequence(params, batch, cfg, opts)
    return x


def prefill_chunk(cfg: ModelConfig, opts: ModelOptions, params, embeds,
                  caches, cache_index, n_valid=None, page_table=None,
                  live_len=None):
    """Positioned prefill over one chunk of precomputed embeddings
    (``embed_prompt`` output sliced to [B, C, d], zero-padded to C).
    Returns (last-valid-position logits [B, 1, V], caches).

    The chunk's queries attend to every cache position ``<=`` their own —
    earlier chunks, and prefix-cache pages the engine never recomputed —
    through the banded chunk core, whose key-axis work covers the live
    prefix ``[0, live_len)`` (``live_len``: static bound on
    ``cache_index + C``, rounded up by the caller to bound retraces; None
    falls back to the full cache view) instead of ``max_seq``. ``n_valid``
    (scalar) marks how many rows are real prompt: padding rows are masked
    out of the cache write path (dense writes dropped, paged writes routed
    to the null page). Only the row at ``n_valid - 1`` runs the lm-head
    projection — a full [C, vocab] projection per chunk would rival the
    chunk's transformer cost, and the caller samples from at most one
    position (the final chunk's last)."""
    B, C, _ = embeds.shape
    positions = jnp.broadcast_to(
        jnp.asarray(cache_index, jnp.int32) +
        jnp.arange(C, dtype=jnp.int32), (B, C))
    x = constrain(embeds, "batch", "act_seq", "act_embed")
    x, caches = stacks.apply_decoder(params["decoder"], x, cfg, opts,
                                     positions, caches=caches,
                                     cache_index=cache_index,
                                     page_table=page_table, n_valid=n_valid,
                                     live_len=live_len)
    last = C - 1 if n_valid is None else jnp.asarray(n_valid, jnp.int32) - 1
    x_last = jax.lax.dynamic_slice_in_dim(x, last, 1, axis=1)
    return _logits(params, x_last, cfg,
                   shard_axis=opts.shard_axis), caches


def decode_step(cfg: ModelConfig, opts: ModelOptions, params, token,
                caches, index, page_table=None):
    """One autoregressive step. token [B,1] int32; index: scalar position or
    per-slot [B] vector (continuous batching). ``page_table`` [B,npg]
    selects the paged cache layout: attention cache leaves are shared
    ``[num_pages, page_size, K, h]`` pools and positions resolve through the
    table (see serving.kv_pool); dense per-slot caches when None. A
    quantized pool (caches built with ``init_caches(kv_dtype="int8"/"fp8")``)
    needs no extra arguments — the int8/fp8 value leaves and their
    ``k_scale``/``v_scale`` siblings ride the cache pytree, and the
    attention layer de/requantizes from their presence alone.
    Returns (logits [B,1,V], new caches)."""
    B = token.shape[0]
    idx = jnp.asarray(index, jnp.int32)
    positions = (jnp.full((B, 1), idx, jnp.int32) if idx.ndim == 0
                 else idx[:, None])
    x = _embed_tokens(params, token, cfg, positions=positions,
                      shard_axis=opts.shard_axis)
    x = constrain(x, "batch", "act_seq", "act_embed")
    x, caches = stacks.apply_decoder(params["decoder"], x, cfg, opts,
                                     positions, caches=caches,
                                     cache_index=index,
                                     page_table=page_table)
    return _logits(params, x, cfg, shard_axis=opts.shard_axis), caches


def draft_step(cfg: ModelConfig, opts: ModelOptions, params, token, caches,
               index, draft_blocks: int, page_table=None, n_valid=None):
    """Layer-truncated decode step — the self-speculative *draft* pass.

    Like ``decode_step`` but only the leading ``draft_blocks`` scanned
    decoder blocks run (``stacks.apply_decoder(n_blocks=...)``); the
    truncated hidden state early-exits through the shared final norm +
    lm head. The draft writes its leading-layer KV into the *same* caches
    the verify pass will rewrite, so no separate draft cache exists —
    rejected positions are neutralized by the verify chunk's full-model
    re-write at those positions. ``n_valid`` (0/1 per slot) masks writes
    for dead slots and positions past the cache capacity (dense scatter
    drop / paged null-page sink). Returns (logits [B,1,V], caches)."""
    B = token.shape[0]
    idx = jnp.asarray(index, jnp.int32)
    positions = (jnp.full((B, 1), idx, jnp.int32) if idx.ndim == 0
                 else idx[:, None])
    x = _embed_tokens(params, token, cfg, positions=positions,
                      shard_axis=opts.shard_axis)
    x = constrain(x, "batch", "act_seq", "act_embed")
    x, caches = stacks.apply_decoder(params["decoder"], x, cfg, opts,
                                     positions, caches=caches,
                                     cache_index=index,
                                     page_table=page_table, n_valid=n_valid,
                                     n_blocks=draft_blocks)
    return _logits(params, x, cfg, shard_axis=opts.shard_axis), caches


def verify_chunk(cfg: ModelConfig, opts: ModelOptions, params, tokens,
                 caches, cache_index, n_valid=None, page_table=None,
                 live_len=None):
    """Speculative *verify* pass: K candidate tokens per slot through the
    full model in one banded chunk-prefill dispatch.

    Like ``prefill_chunk`` with three differences: ``tokens`` [B, K] int32
    are embedded here (the candidates are produced on device, not sliced
    from prompt embeddings); ``cache_index`` may be a per-slot [B] vector —
    each slot's chunk starts at its own live position (positions are
    ``cache_index[:, None] + arange(K)``); and the logits of *every* row
    come back as [B, K, V] — the acceptance rule needs all K next-token
    argmaxes, not just the last valid one (K is small, so the full-chunk
    lm-head projection is cheap, unlike prefill's C-sized chunks).
    ``n_valid`` (scalar or [B]) masks rows past a slot's cache capacity out
    of the write path; their logits are garbage and the engine's budget
    clamp guarantees the acceptance rule never consumes them. The chunk
    write rewrites **all** layers at positions ``cache_index ..
    cache_index+K-1``, which is what erases the draft pass's stale
    leading-layer KV (and any previous round's rejected rows) before
    anything reads those positions."""
    B, K = tokens.shape
    idx = jnp.asarray(cache_index, jnp.int32)
    start = jnp.broadcast_to(idx.reshape(-1, 1), (B, 1))
    positions = start + jnp.arange(K, dtype=jnp.int32)[None]
    x = _embed_tokens(params, tokens, cfg, positions=positions,
                      shard_axis=opts.shard_axis)
    x = constrain(x, "batch", "act_seq", "act_embed")
    x, caches = stacks.apply_decoder(params["decoder"], x, cfg, opts,
                                     positions, caches=caches,
                                     cache_index=cache_index,
                                     page_table=page_table, n_valid=n_valid,
                                     live_len=live_len)
    return _logits(params, x, cfg, shard_axis=opts.shard_axis), caches


def decode_loop(cfg: ModelConfig, opts: ModelOptions, params, token, caches,
                index, n_steps: int, sample_fn=None, page_table=None):
    """``n_steps`` autoregressive decode steps fused on-device via lax.scan —
    one XLA dispatch instead of ``n_steps`` host round-trips.

    index: scalar start position or per-slot [B] vector (continuous
    batching); advanced by 1 every step. ``sample_fn`` maps logits [B,1,V]
    -> tokens [B] (greedy when None). ``page_table`` as in ``decode_step``
    (the table is constant across the fused steps; callers pre-allocate
    pages covering index + n_steps). Quantized paged caches scan through
    unchanged — the int8/fp8 codes and scale leaves are ordinary carry
    state, and the per-step quantize-on-write keeps their dtypes fixed.
    Returns (tokens [B, n_steps], last_token [B,1], caches)."""
    idx = jnp.asarray(index, jnp.int32)

    def step(carry, _):
        tok, caches, idx = carry
        logits, caches = decode_step(cfg, opts, params, tok, caches, idx,
                                     page_table=page_table)
        nxt = (jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
               if sample_fn is None else sample_fn(logits))[:, None]
        return (nxt, caches, idx + 1), nxt[:, 0]

    (last, caches, _), toks = jax.lax.scan(step, (token, caches, idx),
                                           None, length=n_steps)
    return jnp.moveaxis(toks, 0, 1), last, caches


def generate_actions_dit(cfg: ModelConfig, params, cond_hidden, key):
    """Continuous trajectory via the DiT head (cfg.action.mode == 'dit')."""
    assert cfg.action is not None and cfg.action.mode == "dit"
    return action_lib.dit_generate(params["action_dit"], cond_hidden,
                                   cfg.action, key)
