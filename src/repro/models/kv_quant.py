"""Quantized KV page format: int8 / fp8 pool leaves with per-page scales.

The paged KV pool (``stacks.cache_template(paged=True)``) is the natural
quantization boundary for the paper's memory-bound action-generation phase:
decode streams the whole live KV cache per token, so storing pages at 1 byte
per element halves-to-quarters both ``cache_bytes_hwm`` and the bytes the
paged flash-decode kernel must move HBM->VMEM.

Format
------
- ``kv_dtype`` names the pool storage: ``"bf16"`` (unquantized — pages keep
  the cache dtype the caller picks, f32 in the serving engine so the
  paged-vs-dense bit-equality oracle holds), ``"int8"`` (symmetric, codes in
  [-127, 127]) or ``"fp8"`` (``float8_e4m3fn``, max 448).
- Every quantized K/V pool leaf ``[num_pages, page_size, K, h]`` gets a
  sibling scale leaf ``[num_pages, K]`` float32 (per-page, per-KV-head):
  one scale covers all ``page_size * h`` elements a (page, head) pair holds.
  A stored code ``c`` represents the value ``c * scale[page, head]``.
- Scales are **amax-derived**: ``scale = max(|x|) / qmax`` over the covered
  elements. On prefill scatter the amax spans the whole page; on decode the
  scale grows monotonically — writing a token whose amax exceeds the page's
  current range requantizes the already-stored codes under the new scale
  (``decode -> insert -> encode``, drift-free while the scale is unchanged
  because ``encode(decode(c)) == c`` exactly at a fixed scale).
- All-zero pages carry scale 0; ``encode`` guards the division so they
  produce code 0, and 0-codes dequantize to exactly 0 (unwritten rows of a
  partially-filled page never contribute garbage).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

KV_DTYPES = ("bf16", "int8", "fp8")

# smallest representable scale guard: avoids 0/0 on all-zero pages while
# keeping every real amax (>= ~1e-30 is far below KV magnitudes) intact
EPS = 1e-30


def quant_dtype(kv_dtype: str) -> Optional[jnp.dtype]:
    """Pool storage dtype for a ``kv_dtype`` name; None means unquantized."""
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, "
                         f"got {kv_dtype!r}")
    if kv_dtype == "int8":
        return jnp.int8
    if kv_dtype == "fp8":
        return jnp.float8_e4m3fn
    return None


def is_quantized(dtype) -> bool:
    """Whether a concrete array dtype is a quantized pool storage dtype."""
    return jnp.dtype(dtype) in (jnp.dtype(jnp.int8),
                                jnp.dtype(jnp.float8_e4m3fn))


def qmax(dtype) -> float:
    """Largest code magnitude representable by a storage dtype (symmetric
    range: int8 uses [-127, 127], fp8 e4m3fn saturates at 448)."""
    if jnp.dtype(dtype) == jnp.dtype(jnp.int8):
        return 127.0
    if jnp.dtype(dtype) == jnp.dtype(jnp.float8_e4m3fn):
        return float(jnp.finfo(jnp.float8_e4m3fn).max)
    raise ValueError(f"not a quantized KV dtype: {dtype}")


def amax_scale(rows, dtype):
    """Per-(page, head) amax scale for page rows ``[..., ps, K, h]`` ->
    ``[..., K]`` float32 (reduced over the token and head-dim axes)."""
    a = jnp.max(jnp.abs(rows.astype(jnp.float32)), axis=(-3, -1))
    return a / qmax(dtype)


def encode(x, scale, dtype):
    """Quantize fp values ``x`` to codes under ``scale`` (broadcastable).
    int8 rounds-to-nearest and clips to [-127, 127]; fp8 casts (saturating).
    ``scale == 0`` (all-zero page) yields code 0."""
    y = x.astype(jnp.float32) / jnp.maximum(scale, EPS)
    if jnp.dtype(dtype) == jnp.dtype(jnp.int8):
        return jnp.clip(jnp.round(y), -127.0, 127.0).astype(jnp.int8)
    return y.astype(dtype)


def decode(codes, scale):
    """Dequantize codes back to float32 under ``scale`` (broadcastable)."""
    return codes.astype(jnp.float32) * scale


def quantize_page_rows(rows, dtype):
    """Quantize dense page rows ``[..., ps, K, h]`` in one shot.
    Returns ``(codes, scales)`` with scales ``[..., K]`` — the layout the
    pool's sibling scale leaves store and the paged decode kernel reads."""
    scales = amax_scale(rows, dtype)
    return encode(rows, scales[..., None, :, None], dtype), scales
