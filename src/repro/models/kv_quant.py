"""Quantized KV page format: int8 / fp8 pool leaves with per-page scales.

The paged KV pool (``stacks.cache_template(paged=True)``) is the natural
quantization boundary for the paper's memory-bound action-generation phase:
decode streams the whole live KV cache per token, so storing pages at 1 byte
per element halves-to-quarters both ``cache_bytes_hwm`` and the bytes the
paged flash-decode kernel must move HBM->VMEM.

Format
------
- ``kv_dtype`` names the pool storage: ``"bf16"`` (unquantized — pages keep
  the cache dtype the caller picks, f32 in the serving engine so the
  paged-vs-dense bit-equality oracle holds), ``"int8"`` (symmetric, codes in
  [-127, 127]) or ``"fp8"`` (``float8_e4m3fn``, max 448).
- Every quantized K/V pool leaf ``[num_pages, page_size, K, h]`` gets a
  sibling float32 scale leaf whose shape is set by the pool's **scale
  granularity**: ``"head"`` stores ``[num_pages, K]`` (per-page,
  per-KV-head — one scale covers all ``page_size * h`` elements a
  (page, head) pair holds) and ``"token"`` stores
  ``[num_pages, page_size, K]`` (per-row: one scale per (page, token
  offset, head), covering ``h`` elements). A stored code ``c`` represents
  ``c * scale[...]`` under its covering scale.
- Scales are **amax-derived**: ``scale = max(|x|) / qmax`` over the covered
  elements. Under ``"head"`` granularity the scale grows monotonically on
  decode writes — a token whose amax exceeds the page's current range
  requantizes the already-stored codes under the new scale
  (``decode -> insert -> encode``, drift-free while the scale is unchanged
  because ``encode(decode(c)) == c`` exactly at a fixed scale). Under
  ``"token"`` granularity every row quantizes independently and a write
  simply *replaces* the row's codes and scale — no neighbour is ever
  requantized, so rewriting a position is exact regardless of write order.
  That rewrite-stability is what the speculative decode tick requires: its
  verify chunk re-writes positions that rejected draft rows already
  touched, and shared ``"head"`` scales would let a rejected row's amax
  leak into accepted rows on the same page (see docs/speculative.md).
- All-zero pages carry scale 0; ``encode`` guards the division so they
  produce code 0, and 0-codes dequantize to exactly 0 (unwritten rows of a
  partially-filled page never contribute garbage).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

KV_DTYPES = ("bf16", "int8", "fp8")
SCALE_GRANULARITIES = ("head", "token")

# smallest representable scale guard: avoids 0/0 on all-zero pages while
# keeping every real amax (>= ~1e-30 is far below KV magnitudes) intact
EPS = 1e-30


def quant_dtype(kv_dtype: str) -> Optional[jnp.dtype]:
    """Pool storage dtype for a ``kv_dtype`` name; None means unquantized."""
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"kv_dtype must be one of {KV_DTYPES}, "
                         f"got {kv_dtype!r}")
    if kv_dtype == "int8":
        return jnp.int8
    if kv_dtype == "fp8":
        return jnp.float8_e4m3fn
    return None


def is_quantized(dtype) -> bool:
    """Whether a concrete array dtype is a quantized pool storage dtype."""
    return jnp.dtype(dtype) in (jnp.dtype(jnp.int8),
                                jnp.dtype(jnp.float8_e4m3fn))


def qmax(dtype) -> float:
    """Largest code magnitude representable by a storage dtype (symmetric
    range: int8 uses [-127, 127], fp8 e4m3fn saturates at 448)."""
    if jnp.dtype(dtype) == jnp.dtype(jnp.int8):
        return 127.0
    if jnp.dtype(dtype) == jnp.dtype(jnp.float8_e4m3fn):
        return float(jnp.finfo(jnp.float8_e4m3fn).max)
    raise ValueError(f"not a quantized KV dtype: {dtype}")


def amax_scale(rows, dtype, granularity: str = "head"):
    """Amax scale for page rows ``[..., ps, K, h]``: ``"head"`` reduces the
    token and head-dim axes -> ``[..., K]``; ``"token"`` reduces only the
    head-dim axis -> ``[..., ps, K]`` (one scale per row)."""
    axes = (-3, -1) if granularity == "head" else (-1,)
    a = jnp.max(jnp.abs(rows.astype(jnp.float32)), axis=axes)
    return a / qmax(dtype)


def encode(x, scale, dtype):
    """Quantize fp values ``x`` to codes under ``scale`` (broadcastable).
    int8 rounds-to-nearest and clips to [-127, 127]; fp8 casts (saturating).
    ``scale == 0`` (all-zero page) yields code 0."""
    y = x.astype(jnp.float32) / jnp.maximum(scale, EPS)
    if jnp.dtype(dtype) == jnp.dtype(jnp.int8):
        return jnp.clip(jnp.round(y), -127.0, 127.0).astype(jnp.int8)
    return y.astype(dtype)


def decode(codes, scale):
    """Dequantize codes back to float32 under ``scale`` (broadcastable)."""
    return codes.astype(jnp.float32) * scale


def quantize_page_rows(rows, dtype, granularity: str = "head"):
    """Quantize dense page rows ``[..., ps, K, h]`` in one shot.
    Returns ``(codes, scales)`` with scales ``[..., K]`` (``"head"``) or
    ``[..., ps, K]`` (``"token"``) — the layouts the pool's sibling scale
    leaves store and the paged kernels read."""
    scales = amax_scale(rows, dtype, granularity)
    bcast = (scales[..., None, :, None] if granularity == "head"
             else scales[..., None])
    return encode(rows, bcast, dtype), scales


def fake_quantize_tree(params, kv_dtype: str):
    """Round-trip a parameter tree through ``kv_dtype`` codes — the
    self-speculative *weight-quantized draft*: the draft model runs in the
    original dtype but with weights carrying int8/fp8 precision, standing in
    for a deployment where the draft pass streams 1-byte weights from HBM.

    Per-output-channel symmetric scales (amax over every axis but the last)
    keep greedy argmax agreement with the full-precision model high — the
    property the speculative acceptance rate leans on. Only matrices
    (``ndim >= 2``) quantize; vectors (norm gains, biases) pass through
    unchanged, as do integer leaves. Returns a new tree with the original
    dtypes (fake quantization changes values, never types)."""
    dtype = quant_dtype(kv_dtype)
    if dtype is None:
        return params

    def leaf(x):
        if x.ndim < 2 or not jnp.issubdtype(x.dtype, jnp.floating):
            return x
        axes = tuple(range(x.ndim - 1))
        scale = (jnp.max(jnp.abs(x.astype(jnp.float32)), axis=axes,
                         keepdims=True) / qmax(dtype))
        return decode(encode(x, scale, dtype), scale).astype(x.dtype)

    return jax.tree_util.tree_map(leaf, params)
