"""Decoder stacks: templates + scan-over-layers application.

Every architecture is expressed as a repeating *pattern* of sub-layers of
period ``p`` (p=1 for uniform archs, 6 for gemma3's 5:1 local/global, 8 for
jamba's 7:1 mamba/attn). The stack scans over ``L // p`` blocks with stacked
params; the ``L % p`` tail layers are unrolled separately. Every sub-layer
position has a *static* attention window and structure, so sliding-window
layers get banded (linear-FLOP) attention and SSM layers get SSD.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import GLOBAL_WINDOW, ModelConfig, VisionConfig
from repro.distributed.sharding import constrain
from repro.models import kv_quant
from repro.models import layers as L
from repro.models.params import PSpec, stack


# ---------------------------------------------------------------------------
# pattern plan
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class SubKind:
    mixer: str          # 'attn' | 'mamba'
    ffn: str            # 'dense' | 'moe' | 'moe+dense' | 'none'
    cross: bool
    window: int


def _kind_for_layer(cfg: ModelConfig, i: int) -> SubKind:
    mixer = "attn" if cfg.is_attn_layer(i) else "mamba"
    if cfg.family == "ssm" or (mixer == "mamba" and cfg.d_ff == 0 and not cfg.num_experts):
        ffn = "none"
    elif cfg.is_moe_layer(i):
        ffn = "moe+dense" if cfg.dense_residual else "moe"
    elif cfg.d_ff:
        ffn = "dense"
    else:
        ffn = "none"
    window = cfg.layer_window(i) if mixer == "attn" else GLOBAL_WINDOW
    return SubKind(mixer=mixer, ffn=ffn, cross=(cfg.family == "encdec"),
                   window=window)


def stack_plan(cfg: ModelConfig) -> Tuple[int, int, int]:
    """(period, num_blocks, num_tail_layers)."""
    period = int(np.lcm.reduce([len(cfg.window_pattern),
                                max(cfg.attn_every, 1),
                                max(cfg.moe_every, 1)]))
    period = min(period, cfg.num_layers)
    return period, cfg.num_layers // period, cfg.num_layers % period


def sub_kinds(cfg: ModelConfig) -> Tuple[SubKind, ...]:
    period, _, _ = stack_plan(cfg)
    kinds = tuple(_kind_for_layer(cfg, i) for i in range(period))
    # pattern must be consistent across blocks
    for i in range(cfg.num_layers):
        assert _kind_for_layer(cfg, i) == kinds[i % period], (cfg.name, i)
    return kinds


# ---------------------------------------------------------------------------
# templates
# ---------------------------------------------------------------------------

def _norm_template(cfg: ModelConfig, prefix: str, d: int) -> Dict[str, PSpec]:
    t = {prefix + "_w": PSpec((d,), (None,), "ones")}
    if cfg.norm == "layernorm":
        t[prefix + "_b"] = PSpec((d,), (None,), "zeros")
    return t


def attn_template(cfg: ModelConfig, pre: str = "") -> Dict[str, PSpec]:
    d, n, k, h = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    t = {
        pre + "wq": PSpec((d, n, h), ("embed", "heads", "head_dim"), fan_in=d),
        pre + "wk": PSpec((d, k, h), ("embed", "kv_heads", "head_dim"), fan_in=d),
        pre + "wv": PSpec((d, k, h), ("embed", "kv_heads", "head_dim"), fan_in=d),
        pre + "wo": PSpec((n, h, d), ("heads", "head_dim", "embed"), fan_in=n * h),
    }
    if cfg.qkv_bias:
        t[pre + "bq"] = PSpec((n, h), ("heads", "head_dim"), "zeros")
        t[pre + "bk"] = PSpec((k, h), ("kv_heads", "head_dim"), "zeros")
        t[pre + "bv"] = PSpec((k, h), ("kv_heads", "head_dim"), "zeros")
    return t


def mlp_template(cfg: ModelConfig) -> Dict[str, PSpec]:
    d, f = cfg.d_model, cfg.d_ff
    t = {"wi": PSpec((d, f), ("embed", "mlp"), fan_in=d),
         "wo_mlp": PSpec((f, d), ("mlp", "embed"), fan_in=f)}
    if cfg.act in ("silu", "gelu"):
        t["wg"] = PSpec((d, f), ("embed", "mlp"), fan_in=d)
    return t


def moe_template(cfg: ModelConfig) -> Dict[str, PSpec]:
    d, f = cfg.d_model, cfg.moe_d_ff
    e = max(cfg.num_experts_padded, cfg.num_experts)
    return {
        "router": PSpec((d, e), ("embed", None), fan_in=d),
        "moe_wi": PSpec((e, d, f), ("experts", "embed", "mlp"), fan_in=d),
        "moe_wg": PSpec((e, d, f), ("experts", "embed", "mlp"), fan_in=d),
        "moe_wo": PSpec((e, f, d), ("experts", "mlp", "embed"), fan_in=f),
    }


def mamba_template(cfg: ModelConfig) -> Dict[str, PSpec]:
    d = cfg.d_model
    d_in, H, P, N, G, conv_ch = L.mamba_dims(cfg)
    return {
        "w_z": PSpec((d, d_in), ("embed", "ssm_inner"), fan_in=d),
        "w_xbc": PSpec((d, conv_ch), ("embed", "ssm_inner"), fan_in=d),
        "w_dt": PSpec((d, H), ("embed", None), fan_in=d),
        "conv_w": PSpec((cfg.ssm_conv, conv_ch), ("conv", "ssm_inner"),
                        fan_in=cfg.ssm_conv),
        "conv_b": PSpec((conv_ch,), ("ssm_inner",), "zeros"),
        "A_log": PSpec((H,), (None,), "ssm_a"),
        "dt_bias": PSpec((H,), (None,), "ssm_dt"),
        "d_skip": PSpec((H,), (None,), "ones"),
        "mamba_norm_w": PSpec((d_in,), (None,), "ones"),
        "w_out": PSpec((d_in, d), ("ssm_inner", "embed"), fan_in=d_in),
    }


def layer_template(cfg: ModelConfig, kind: SubKind) -> Dict[str, PSpec]:
    t: Dict[str, PSpec] = {}
    t.update(_norm_template(cfg, "ln1", cfg.d_model))
    if kind.mixer == "attn":
        t.update(attn_template(cfg))
        if kind.cross:
            t.update(_norm_template(cfg, "ln_cross", cfg.d_model))
            t.update(attn_template(cfg, pre="x"))
    else:
        t.update(mamba_template(cfg))
    if kind.ffn != "none":
        t.update(_norm_template(cfg, "ln2", cfg.d_model))
    if kind.ffn in ("dense", "moe+dense"):
        t.update(mlp_template(cfg))
    if kind.ffn in ("moe", "moe+dense"):
        t.update(moe_template(cfg))
    return t


def decoder_template(cfg: ModelConfig) -> Dict:
    period, nblocks, ntail = stack_plan(cfg)
    kinds = sub_kinds(cfg)
    block = {f"sub{j}": layer_template(cfg, kinds[j]) for j in range(period)}
    t = {"blocks": stack(block, nblocks, "layers")}
    if ntail:
        t["tail"] = {f"tail{j}": layer_template(cfg, kinds[j])
                     for j in range(ntail)}
    return t


def tower_template(enc: VisionConfig, d_out: int) -> Dict:
    """Vision/audio encoder tower (pre-LN MHA + plain-gelu MLP) + projector."""
    d, n, f = enc.d_model, enc.num_heads, enc.d_ff
    h = d // n
    layer = {
        "ln1_w": PSpec((d,), (None,), "ones"),
        "ln1_b": PSpec((d,), (None,), "zeros"),
        "wq": PSpec((d, n, h), ("embed", "heads", "head_dim"), fan_in=d),
        "wk": PSpec((d, n, h), ("embed", "heads", "head_dim"), fan_in=d),
        "wv": PSpec((d, n, h), ("embed", "heads", "head_dim"), fan_in=d),
        "wo": PSpec((n, h, d), ("heads", "head_dim", "embed"), fan_in=d),
        "ln2_w": PSpec((d,), (None,), "ones"),
        "ln2_b": PSpec((d,), (None,), "zeros"),
        "wi": PSpec((d, f), ("embed", "mlp"), fan_in=d),
        "wo_mlp": PSpec((f, d), ("mlp", "embed"), fan_in=f),
    }
    return {
        "in_proj": PSpec((enc.embed_dim, d), (None, "embed"), fan_in=enc.embed_dim),
        "pos": PSpec((enc.num_tokens, d), (None, None), "pos"),
        "stack": stack(layer, enc.num_layers, "layers"),
        "final_ln_w": PSpec((d,), (None,), "ones"),
        "final_ln_b": PSpec((d,), (None,), "zeros"),
        "out_proj": PSpec((d, d_out), ("embed", None), fan_in=d),
    }


# ---------------------------------------------------------------------------
# application
# ---------------------------------------------------------------------------

def apply_sublayer(p, x, cfg: ModelConfig, opts: L.ModelOptions, kind: SubKind,
                   positions, cache=None, cache_index=None, ctx=None,
                   page_table=None, n_valid=None, live_len=None):
    """One transformer sub-layer. Returns (x, new_cache_dict)."""
    new_cache: Dict = {}
    h = L.apply_norm(p, x, cfg, "ln1")
    if kind.mixer == "attn":
        attn_cache = None
        if cache is not None and "k" in cache:
            # quantized paged caches carry per-page scale siblings; the
            # 4-tuple form tells layers.attention to de/requantize
            attn_cache = (cache["k"], cache["v"])
            if "k_scale" in cache:
                attn_cache += (cache["k_scale"], cache["v_scale"])
        a, attn_cache = L.attention(p, h, cfg, opts, kind.window, positions,
                                    cache=attn_cache, cache_index=cache_index,
                                    page_table=page_table, n_valid=n_valid,
                                    live_len=live_len)
        if attn_cache is not None:
            new_cache["k"], new_cache["v"] = attn_cache[:2]
            if len(attn_cache) == 4:
                new_cache["k_scale"], new_cache["v_scale"] = attn_cache[2:]
        x = x + a
        if kind.cross:
            hc = L.apply_norm(p, x, cfg, "ln_cross")
            if cache is not None and "xk" in cache and ctx is None:
                kv = (cache["xk"], cache["xv"])
                new_cache["xk"], new_cache["xv"] = kv
            else:
                xk = jnp.einsum("btd,dkh->btkh", ctx, p["xwk"])
                xv = jnp.einsum("btd,dkh->btkh", ctx, p["xwv"])
                if cfg.qkv_bias:
                    xk = xk + p["xbk"].astype(xk.dtype)
                    xv = xv + p["xbv"].astype(xv.dtype)
                kv = (xk, xv)
                if cache is not None:
                    new_cache["xk"], new_cache["xv"] = kv
            a, _ = L.attention(p, hc, cfg, opts, GLOBAL_WINDOW, positions,
                               ctx=kv, ctx_prefix="x", causal=False)
            x = x + a
    else:
        state = cache.get("ssm") if cache else None
        conv_state = cache.get("conv") if cache else None
        decode = cache is not None and x.shape[1] == 1
        m, state, conv_state = L.mamba_block(p, h, cfg, opts,
                                             state=state,
                                             conv_state=conv_state,
                                             decode=decode)
        if cache is not None:
            new_cache["ssm"] = state.astype(cache["ssm"].dtype)
            new_cache["conv"] = conv_state.astype(cache["conv"].dtype)
        x = x + m

    if kind.ffn != "none":
        h = L.apply_norm(p, x, cfg, "ln2")
        y = 0.0
        if kind.ffn in ("dense", "moe+dense"):
            y = y + L.mlp(p, h, cfg, shard_axis=opts.shard_axis)
        if kind.ffn in ("moe", "moe+dense"):
            y = y + L.moe(p, h, cfg, opts)
        x = x + y
    x = constrain(x, "batch", "act_seq", "act_embed")
    return x, new_cache


def apply_decoder(params, x, cfg: ModelConfig, opts: L.ModelOptions,
                  positions, caches=None, cache_index=None, ctx=None,
                  train: bool = False, page_table=None, n_valid=None,
                  live_len=None, n_blocks: Optional[int] = None):
    """Run the full decoder stack. Returns (x, new_caches).

    ``page_table`` [B, npg] switches attention cache leaves to the paged
    layout (shared per-layer pools + per-slot tables); it is a single table
    shared by every layer, captured as a constant by the layer scan.
    ``n_valid`` masks a prefill chunk's padding rows out of the cache write
    path; ``live_len`` (static) bounds the banded chunk core's key axis to
    the live cache prefix (see layers.attention).

    ``n_blocks`` (static) truncates the stack to its leading ``n_blocks``
    scanned blocks — the self-speculative *draft* pass: the shallow model
    shares the full model's parameters and caches (its leading-layer KV
    writes land in the real cache, where the verify pass overwrites them),
    runs ``n_blocks / nblocks`` of the depth, and the caller early-exits
    through the final norm + lm head. The tail sublayers are skipped and
    their caches pass through untouched (the returned tree keeps the full
    structure, so jitted carries are stable)."""
    period, nblocks, ntail = stack_plan(cfg)
    kinds = sub_kinds(cfg)
    if n_blocks is not None:
        if not 0 < n_blocks <= nblocks:
            raise ValueError(f"n_blocks must be in 1..{nblocks}, "
                             f"got {n_blocks}")
        truncate = n_blocks < nblocks or ntail > 0
    else:
        truncate = False

    def block_body(x, block_params, block_caches):
        new_caches = {}
        for j in range(period):
            sub_c = block_caches.get(f"sub{j}") if block_caches else None
            sub_fn = functools.partial(
                apply_sublayer, cfg=cfg, opts=opts, kind=kinds[j],
                positions=positions, cache=sub_c, cache_index=cache_index,
                ctx=ctx, page_table=page_table, n_valid=n_valid,
                live_len=live_len)
            if train and opts.remat and opts.remat_sublayers and period > 1:
                sub_fn = jax.checkpoint(
                    sub_fn, policy=jax.checkpoint_policies.nothing_saveable)
            x, nc = sub_fn(block_params[f"sub{j}"], x)
            if nc:
                new_caches[f"sub{j}"] = nc
        return x, new_caches

    body = block_body
    if train and opts.remat:
        body = jax.checkpoint(block_body,
                              policy=jax.checkpoint_policies.nothing_saveable)

    block_caches = caches.get("blocks") if caches else None
    block_params = params["blocks"]
    if truncate:
        # leading-blocks draft: slice the stacked layer axis (static), scan
        # the shallow stack, splice its cache updates back into the full tree
        block_params = jax.tree_util.tree_map(lambda l: l[:n_blocks],
                                              block_params)
        if block_caches is not None:
            block_caches_in = jax.tree_util.tree_map(lambda l: l[:n_blocks],
                                                     block_caches)
    else:
        block_caches_in = block_caches
    n_eff = n_blocks if truncate else nblocks
    unroll = n_eff if opts.unroll_layers else 1
    if block_caches is None:
        # scan without cache xs
        def scan_nc(carry_x, bp):
            x, _ = body(carry_x, bp, None)
            return x, None
        x, _ = jax.lax.scan(scan_nc, x, block_params, unroll=unroll)
        new_caches = None
    else:
        def scan_c(carry_x, pc):
            bp, bc = pc
            x, nc = body(carry_x, bp, bc)
            return x, nc
        x, new_block_caches = jax.lax.scan(scan_c, x,
                                           (block_params, block_caches_in),
                                           unroll=unroll)
        if truncate:
            new_block_caches = jax.tree_util.tree_map(
                lambda full, new: full.at[:n_blocks].set(new),
                block_caches, new_block_caches)
        new_caches = {"blocks": new_block_caches}

    if truncate:
        if new_caches is not None and ntail and caches and "tail" in caches:
            new_caches["tail"] = caches["tail"]
        return x, new_caches

    if ntail:
        tail_new = {}
        for j in range(ntail):
            tc = caches["tail"].get(f"tail{j}") if caches else None
            x, nc = apply_sublayer(params["tail"][f"tail{j}"], x, cfg, opts,
                                   kinds[j], positions, cache=tc,
                                   cache_index=cache_index, ctx=ctx,
                                   page_table=page_table, n_valid=n_valid,
                                   live_len=live_len)
            if nc:
                tail_new[f"tail{j}"] = nc
        if new_caches is not None:
            new_caches["tail"] = tail_new
    return x, new_caches


def apply_tower(params, embeds, enc: VisionConfig, opts: L.ModelOptions):
    """Vision/audio tower over stubbed frontend embeddings [B,T,embed_dim]."""
    x = jnp.einsum("bte,ed->btd", embeds, params["in_proj"])
    x = x + params["pos"].astype(x.dtype)[None]
    n, d = enc.num_heads, enc.d_model
    h = d // n

    def body(x, p):
        y = L.layer_norm(x, p["ln1_w"], p["ln1_b"])
        q = jnp.einsum("btd,dnh->btnh", y, p["wq"])
        k = jnp.einsum("btd,dnh->btnh", y, p["wk"])
        v = jnp.einsum("btd,dnh->btnh", y, p["wv"])
        pos = jnp.arange(x.shape[1])
        a = L.attention_dense(q, k, v, pos, pos, GLOBAL_WINDOW, causal=False)
        x = x + jnp.einsum("btnh,nhd->btd", a, p["wo"])
        y = L.layer_norm(x, p["ln2_w"], p["ln2_b"])
        y = jax.nn.gelu(jnp.einsum("btd,df->btf", y, p["wi"]))
        x = x + jnp.einsum("btf,fd->btd", y, p["wo_mlp"])
        return x, None

    x, _ = jax.lax.scan(body, x, params["stack"])
    x = L.layer_norm(x, params["final_ln_w"], params["final_ln_b"])
    return jnp.einsum("btd,de->bte", x, params["out_proj"])


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def cache_template(cfg: ModelConfig, batch: int, max_seq: int,
                   dtype=jnp.bfloat16, opts: Optional[L.ModelOptions] = None,
                   *, paged: bool = False, num_pages: int = 0,
                   page_size: int = 0, kv_dtype: str = "bf16",
                   scale_granularity: str = "head"):
    """Shape tree (PSpec) for the decode cache; concrete zeros via init_caches.

    Dense (default): attention K/V leaves are per-slot ``[batch, seq, K, h]``
    buffers over-allocated at ``max_seq``. Paged: attention K/V leaves become
    shared pools ``[num_pages, page_size, K, h]`` addressed through a
    per-slot page table (see serving.kv_pool); only attention k/v move to
    the pool — SSM/conv state and cross-attention K/V keep the slot-batched
    layout (they are O(1) or prompt-sized per slot, not decode-growing).

    ``kv_dtype`` (paged only) selects the pool storage: ``"bf16"`` keeps
    ``dtype``; ``"int8"``/``"fp8"`` store 1-byte codes and every K/V pool
    leaf gets a sibling float32 scale leaf (``k_scale``/``v_scale``) whose
    shape follows ``scale_granularity``: ``"head"`` -> ``[num_pages, K]``
    (per-page-per-head, the compact default), ``"token"`` ->
    ``[num_pages, page_size, K]`` (per-row — rewrite-stable, required by
    speculative decode; see models.kv_quant)."""
    period, nblocks, ntail = stack_plan(cfg)
    kinds = sub_kinds(cfg)
    opts = opts or L.ModelOptions()
    quantized = kv_quant.quant_dtype(kv_dtype) is not None
    if scale_granularity not in kv_quant.SCALE_GRANULARITIES:
        raise ValueError(f"scale_granularity must be one of "
                         f"{kv_quant.SCALE_GRANULARITIES}, "
                         f"got {scale_granularity!r}")
    if paged:
        if num_pages <= 0 or page_size <= 0:
            raise ValueError("paged cache_template needs num_pages/page_size")
        if opts.window_cache:
            raise ValueError("window_cache (per-layer ring buffers) and the "
                             "paged KV pool are mutually exclusive")
    elif quantized:
        raise ValueError("kv_dtype quantization requires the paged layout "
                         "(the page pool is the quantization boundary)")

    def sub_cache(kind: SubKind):
        c: Dict[str, PSpec] = {}
        if kind.mixer == "attn":
            if paged:
                c["k"] = PSpec((num_pages, page_size, cfg.num_kv_heads,
                                cfg.head_dim),
                               (None, None, "act_kv_heads", None))
                c["v"] = PSpec((num_pages, page_size, cfg.num_kv_heads,
                                cfg.head_dim),
                               (None, None, "act_kv_heads", None))
                if quantized:
                    sshape, sspec = ((num_pages, cfg.num_kv_heads),
                                     (None, "act_kv_heads"))
                    if scale_granularity == "token":
                        sshape = (num_pages, page_size, cfg.num_kv_heads)
                        sspec = (None, None, "act_kv_heads")
                    c["k_scale"] = PSpec(sshape, sspec)
                    c["v_scale"] = PSpec(sshape, sspec)
                if kind.cross and cfg.encoder:
                    c["xk"] = PSpec((batch, cfg.encoder.num_tokens,
                                     cfg.num_kv_heads, cfg.head_dim),
                                    ("batch", None, "act_kv_heads", None))
                    c["xv"] = PSpec((batch, cfg.encoder.num_tokens,
                                     cfg.num_kv_heads, cfg.head_dim),
                                    ("batch", None, "act_kv_heads", None))
                return c
            seq = max_seq
            if opts.window_cache and kind.window != GLOBAL_WINDOW:
                seq = min(max_seq, kind.window)
            c["k"] = PSpec((batch, seq, cfg.num_kv_heads, cfg.head_dim),
                           ("batch", "kv_seq", "act_kv_heads", None))
            c["v"] = PSpec((batch, seq, cfg.num_kv_heads, cfg.head_dim),
                           ("batch", "kv_seq", "act_kv_heads", None))
            if kind.cross and cfg.encoder:
                c["xk"] = PSpec((batch, cfg.encoder.num_tokens,
                                 cfg.num_kv_heads, cfg.head_dim),
                                ("batch", None, "act_kv_heads", None))
                c["xv"] = PSpec((batch, cfg.encoder.num_tokens,
                                 cfg.num_kv_heads, cfg.head_dim),
                                ("batch", None, "act_kv_heads", None))
        else:
            d_in, H, P, N, G, conv_ch = L.mamba_dims(cfg)
            c["ssm"] = PSpec((batch, H, P, N), ("batch", None, None, None))
            c["conv"] = PSpec((batch, cfg.ssm_conv - 1, conv_ch),
                              ("batch", None, "ssm_inner"))
        return c

    block = {f"sub{j}": sub_cache(kinds[j]) for j in range(period)}
    t = {"blocks": stack(block, nblocks, "layers")}
    if ntail:
        t["tail"] = {f"tail{j}": sub_cache(kinds[j]) for j in range(ntail)}
    return t


def cache_batch_axis(path) -> int:
    """Batch axis of a cache leaf, from its position in the cache pytree.

    Leaves under ``blocks`` are layer-stacked by ``stack(...)`` so batch sits
    behind the scan dim at axis 1; ``tail`` leaves carry batch at axis 0. This
    is the explicit annotation the serving engine's slot scatter relies on
    (shape inference breaks down when slot and prefill caches coincide, e.g.
    n_slots == 1)."""
    key = getattr(path[0], "key", path[0])
    return 1 if key == "blocks" else 0


def cache_dtype(path_key: str, dtype, kv_dtype: str = "bf16"):
    # SSM recurrent state is kept fp32 (it integrates over the whole stream);
    # quantization scales are fp32 metadata; quantized K/V pool leaves store
    # 1-byte codes (see models.kv_quant).
    if path_key == "ssm":
        return jnp.float32
    if path_key in ("k_scale", "v_scale"):
        return jnp.float32
    q = kv_quant.quant_dtype(kv_dtype)
    if q is not None and path_key in ("k", "v"):
        return q
    return dtype


def init_caches(cfg: ModelConfig, batch: int, max_seq: int,
                dtype=jnp.bfloat16, opts=None, *, paged: bool = False,
                num_pages: int = 0, page_size: int = 0,
                kv_dtype: str = "bf16", scale_granularity: str = "head"):
    t = cache_template(cfg, batch, max_seq, dtype, opts, paged=paged,
                       num_pages=num_pages, page_size=page_size,
                       kv_dtype=kv_dtype,
                       scale_granularity=scale_granularity)
    return jax.tree_util.tree_map_with_path(
        lambda path, s: jnp.zeros(s.shape, cache_dtype(path[-1].key, dtype,
                                                       kv_dtype)),
        t, is_leaf=lambda x: isinstance(x, PSpec))


def is_paged_leaf(path) -> bool:
    """Whether a cache-pytree leaf lives in the paged KV pool layout —
    attention ``k``/``v`` value leaves and their ``k_scale``/``v_scale``
    quantization-scale siblings (leading axis = num_pages) — rather than the
    slot-batched layout (``xk``/``xv``/``ssm``/``conv``, leading axis =
    batch). Only meaningful for caches built with ``paged=True``."""
    key = getattr(path[-1], "key", path[-1])
    return key in ("k", "v", "k_scale", "v_scale")


def is_scale_leaf(path) -> bool:
    """Whether a cache-pytree leaf is a quantization scale sibling of a
    paged K/V pool leaf (``[num_pages, K]`` float32 at ``"head"``
    granularity, ``[num_pages, page_size, K]`` at ``"token"``)."""
    key = getattr(path[-1], "key", path[-1])
    return key in ("k_scale", "v_scale")
