from repro.data.pipeline import Prefetcher, lm_batches, vla_batches

__all__ = ["Prefetcher", "lm_batches", "vla_batches"]
