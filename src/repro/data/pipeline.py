"""Synthetic data pipelines with host-side prefetch.

Two sources:
- ``lm_batches``: deterministic synthetic token streams (seeded per shard,
  so every data-parallel host draws disjoint data — the multi-host layout).
- ``vla_batches``: synthetic VLA episodes (image patch embeddings +
  instruction tokens + action-token labels) matching the stubbed frontends.

``Prefetcher`` double-buffers batches on a background thread so host data
production overlaps device compute (the standard input-pipeline overlap).
"""
from __future__ import annotations

import queue
import threading
from typing import Dict, Iterator, Optional

import numpy as np

from repro.configs.base import ModelConfig


def lm_batches(cfg: ModelConfig, batch: int, seq: int, *, seed: int = 0,
               shard: int = 0, num_shards: int = 1,
               steps: Optional[int] = None) -> Iterator[Dict[str, np.ndarray]]:
    """Synthetic causal-LM batches: [batch, seq] int32 in [0, vocab)."""
    rng = np.random.default_rng(seed * 100_003 + shard)
    local = batch // num_shards
    i = 0
    while steps is None or i < steps:
        tokens = rng.integers(0, cfg.vocab_size, (local, seq), dtype=np.int32)
        out = {"tokens": tokens}
        if cfg.vision is not None:
            out["patches"] = rng.standard_normal(
                (local, cfg.vision.num_tokens, cfg.vision.embed_dim),
                dtype=np.float32) * 0.1
        if cfg.encoder is not None:
            out["frames"] = rng.standard_normal(
                (local, cfg.encoder.num_tokens, cfg.encoder.embed_dim),
                dtype=np.float32) * 0.1
        yield out
        i += 1


def vla_batches(cfg: ModelConfig, batch: int, *, seed: int = 0,
                steps: Optional[int] = None) -> Iterator[Dict[str, np.ndarray]]:
    """Synthetic VLA episodes: instruction + image patches + action labels
    (action tokens drawn from the top of the vocab, MolmoAct-style binning)."""
    assert cfg.vision is not None
    a = cfg.action
    n_act = a.num_action_tokens if a else 24
    rng = np.random.default_rng(seed + 17)
    i = 0
    while steps is None or i < steps:
        instr = rng.integers(0, cfg.vocab_size, (batch, cfg.n_prompt_tokens),
                             dtype=np.int32)
        cot = rng.integers(0, cfg.vocab_size, (batch, cfg.n_cot_tokens),
                           dtype=np.int32)
        act = rng.integers(cfg.vocab_size - 256, cfg.vocab_size,
                           (batch, n_act), dtype=np.int32)
        yield {
            "tokens": np.concatenate([instr, cot, act], axis=1),
            "patches": rng.standard_normal(
                (batch, cfg.vision.num_tokens, cfg.vision.embed_dim),
                dtype=np.float32) * 0.1,
        }
        i += 1


class Prefetcher:
    """Background-thread double buffering over any batch iterator."""

    def __init__(self, it: Iterator, depth: int = 2):
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._done = object()

        def worker():
            for item in it:
                self._q.put(item)
            self._q.put(self._done)

        self._t = threading.Thread(target=worker, daemon=True)
        self._t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self._q.get()
        if item is self._done:
            raise StopIteration
        return item
