from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state, lr_at
from repro.training.train_step import (TrainConfig, init_train_state, lm_loss,
                                       make_train_step)

__all__ = ["AdamWConfig", "TrainConfig", "adamw_update", "init_opt_state",
           "init_train_state", "lm_loss", "lr_at", "make_train_step"]
