"""Training step: causal-LM cross entropy, microbatched gradient
accumulation (lets XLA overlap the DP all-reduce of microbatch i's grads
with microbatch i+1's backward), remat via the stacks' scanned bodies.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import constrain
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.training import compress as C
from repro.training.optimizer import AdamWConfig, adamw_update, init_opt_state


@dataclass(frozen=True)
class TrainConfig:
    opt: AdamWConfig = AdamWConfig()
    microbatches: int = 1
    compress_grads: bool = False
    z_loss: float = 1e-4          # logit regularizer (PaLM-style)


def lm_loss(cfg: ModelConfig, opts: ModelOptions, params, batch,
            z_loss: float = 0.0):
    """Next-token CE over batch['tokens']; vision/audio prefix positions and
    padding (token == -1) are masked out of the loss."""
    tokens = batch["tokens"]
    logits = M.forward(cfg, opts, params, batch, train=True)
    n_prefix = logits.shape[1] - tokens.shape[1]
    logits = logits[:, n_prefix:]
    targets = tokens[:, 1:]
    logits = logits[:, :-1].astype(jnp.float32)
    mask = (targets >= 0).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(
        logits, jnp.maximum(targets, 0)[..., None], -1)[..., 0]
    nll = (lse - picked) * mask
    loss = nll.sum() / jnp.maximum(mask.sum(), 1.0)
    if z_loss:
        loss = loss + z_loss * (jnp.square(lse) * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss


def make_train_step(cfg: ModelConfig, opts: ModelOptions, tcfg: TrainConfig):
    """Returns train_step(params, opt_state, batch) -> (params, opt_state,
    metrics). batch tokens [B_global, S] (+ modality stubs)."""

    def grads_of(params, batch):
        return jax.value_and_grad(
            lambda p: lm_loss(cfg, opts, p, batch, tcfg.z_loss))(params)

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            def micro(carry, mb):
                acc, loss_acc = carry
                loss, g = grads_of(params, mb)
                acc = jax.tree.map(jnp.add, acc, g)
                return (acc, loss_acc + loss), None
            mbs = jax.tree.map(
                lambda x: x.reshape((tcfg.microbatches,
                                     x.shape[0] // tcfg.microbatches)
                                    + x.shape[1:]), batch)
            zero = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                                params)
            (grads, loss), _ = jax.lax.scan(micro, (zero, 0.0), mbs)
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
            loss = loss / tcfg.microbatches
        else:
            loss, grads = grads_of(params, batch)

        if tcfg.compress_grads:
            grads, err = C.compress_grads(grads, opt_state["error"])
        new_params, new_inner, metrics = adamw_update(
            tcfg.opt, grads, opt_state["inner"], params)
        new_state = {"inner": new_inner}
        if tcfg.compress_grads:
            new_state["error"] = err
        metrics = dict(metrics, loss=loss)
        return new_params, new_state, metrics

    return train_step


def init_train_state(cfg: ModelConfig, tcfg: TrainConfig, params):
    state = {"inner": init_opt_state(tcfg.opt, params)}
    if tcfg.compress_grads:
        state["error"] = C.init_error_state(params)
    return state
