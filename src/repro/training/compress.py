"""Gradient compression for slow inter-pod links: per-tensor int8
quantization with error feedback (the residual is carried in the optimizer
state, so compression error does not bias the long-run gradient estimate).

Applied *before* the DP all-reduce boundary: under pjit the all-reduce of a
quantize->dequantize'd tensor moves the same bytes as fp32 on the wire only
if XLA keeps fp32 — so the compressed path reduces int8 values and rescales
afterwards via shard_map when `wire_int8=True` (used by launch/train.py for
the multi-pod mesh).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compress_grads(grads, error_state):
    """Quantize grads + error feedback. Returns (decompressed, new_error)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e
        q, s = quantize_int8(g32)
        dq = dequantize_int8(q, s)
        return dq.astype(g.dtype), (g32 - dq)
    out = jax.tree.map(one, grads, error_state)
    deq = jax.tree.map(lambda t: t[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    err = jax.tree.map(lambda t: t[1], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    return deq, err


def init_error_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
