"""AdamW implemented from scratch (no optax), with sharded moments.

Moments inherit the parameter shardings (pass the same NamedShardings used
for params), so optimizer state scales with the model under FSDP/TP. For
100B+ models the moments can be kept in bf16 (`moment_dtype`) — an 8-bit-
Adam-style state compression documented in EXPERIMENTS.md.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    moment_dtype: Any = jnp.float32


def lr_at(cfg: AdamWConfig, step):
    """Linear warmup + cosine decay."""
    step = jnp.asarray(step, jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(cfg: AdamWConfig, params):
    zeros = lambda p: jnp.zeros(p.shape, cfg.moment_dtype)
    return {"mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "count": jnp.zeros((), jnp.int32)}


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(cfg: AdamWConfig, grads, opt_state, params):
    """Returns (new_params, new_opt_state, metrics)."""
    count = opt_state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = lr_at(cfg, count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g = g.astype(jnp.float32) * scale
        mu32 = cfg.b1 * mu.astype(jnp.float32) + (1 - cfg.b1) * g
        nu32 = cfg.b2 * nu.astype(jnp.float32) + (1 - cfg.b2) * jnp.square(g)
        step = (mu32 / b1c) / (jnp.sqrt(nu32 / b2c) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step + decay)
        return (new_p.astype(p.dtype), mu32.astype(cfg.moment_dtype),
                nu32.astype(cfg.moment_dtype))

    out = jax.tree.map(upd, params, grads, opt_state["mu"], opt_state["nu"])
    new_params = jax.tree.map(lambda t: t[0], out,
                              is_leaf=lambda x: isinstance(x, tuple))
    new_mu = jax.tree.map(lambda t: t[1], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    new_nu = jax.tree.map(lambda t: t[2], out,
                          is_leaf=lambda x: isinstance(x, tuple))
    return new_params, {"mu": new_mu, "nu": new_nu, "count": count}, \
        {"grad_norm": gnorm, "lr": lr}
