from repro.checkpoint import store
from repro.checkpoint.resilience import (ResilientLoop, StepFailure,
                                         elastic_shrink)
from repro.checkpoint.store import latest_step, restore, save

__all__ = ["ResilientLoop", "StepFailure", "elastic_shrink", "latest_step",
           "restore", "save", "store"]
