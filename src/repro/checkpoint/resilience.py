"""Fault tolerance for the training loop.

- ``ResilientLoop``: wraps a step function with retry + restore-from-latest;
  a fault hook lets tests inject failures deterministically.
- ``elastic_shrink``: on permanent node loss, shrink the data axis, rebuild
  the mesh and reshard the restored state (checkpoint-restore path) —
  training resumes at reduced throughput instead of stopping. Stragglers are
  handled the same way as failures after `straggler_timeout` (detect-and-
  evict, the standard large-fleet policy).
"""
from __future__ import annotations

import logging
import time
from typing import Callable, Optional

import jax

from repro.checkpoint import store

log = logging.getLogger(__name__)


class StepFailure(RuntimeError):
    pass


class ResilientLoop:
    def __init__(self, step_fn: Callable, ckpt_dir: str, save_every: int = 50,
                 max_retries: int = 3, fault_hook: Optional[Callable] = None,
                 async_save: bool = True):
        self.step_fn = step_fn
        self.ckpt_dir = ckpt_dir
        self.save_every = save_every
        self.max_retries = max_retries
        self.fault_hook = fault_hook
        self.async_save = async_save
        self._pending = None
        self.retries = 0
        self.restores = 0

    def _maybe_save(self, step, state):
        if step % self.save_every == 0:
            if self._pending is not None:
                self._pending.join()
            self._pending = store.save(self.ckpt_dir, step, state,
                                       async_=self.async_save)

    def run(self, state, start_step: int, num_steps: int, *args):
        """Runs ``state = step_fn(state, step, *args)`` with retry+restore."""
        step = start_step
        last_good = start_step
        while step < start_step + num_steps:
            try:
                if self.fault_hook is not None:
                    self.fault_hook(step)
                state = self.step_fn(state, step, *args)
                self._maybe_save(step, state)
                if step % self.save_every == 0:
                    last_good = step
                step += 1
                self.retries = 0
            except StepFailure as e:  # injected/detected node failure
                self.retries += 1
                log.warning("step %d failed (%s); retry %d", step, e,
                            self.retries)
                if self.retries > self.max_retries:
                    raise
                ck = store.latest_step(self.ckpt_dir)
                if ck is not None and ck <= step:
                    if self._pending is not None:
                        self._pending.join()
                        self._pending = None
                    state = store.restore(self.ckpt_dir, ck, state)
                    step = ck + 1
                    self.restores += 1
        if self._pending is not None:
            self._pending.join()
        return state, step


def elastic_shrink(state, old_mesh, make_mesh: Callable[[int], "jax.sharding.Mesh"],
                   sharding_fn: Callable, lost_nodes: int = 1):
    """Rebuild a smaller mesh after node loss and reshard `state` onto it.

    make_mesh(new_data_size) -> Mesh; sharding_fn(tree, mesh) -> shardings.
    Returns (new_state, new_mesh)."""
    old_data = old_mesh.shape["data"]
    new_data = old_data - lost_nodes
    assert new_data >= 1, "cannot shrink below one data shard"
    new_mesh = make_mesh(new_data)
    shardings = sharding_fn(state, new_mesh)
    new_state = jax.tree.map(
        lambda x, s: jax.device_put(jax.device_get(x), s)
        if s is not None else x, state, shardings)
    return new_state, new_mesh
