"""Sharded, atomic, optionally-async checkpointing.

Layout: <dir>/step_<n>/ with one .npy per pytree leaf (path-encoded name)
plus index.json (treedef + shapes + dtypes + step). Commit is atomic via
write-to-tmp + os.rename, so a crash mid-save never corrupts the latest
checkpoint. On multi-host deployments each host writes only its addressable
shards (here: single host writes everything); restore device_puts with the
target shardings, which is also the elastic re-mesh path — loading onto a
*different* mesh just means different target shardings.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Dict, Optional

import jax
import ml_dtypes
import numpy as np


def _to_numpy(v) -> "np.ndarray":
    v = np.asarray(v)
    if v.dtype == ml_dtypes.bfloat16:
        return v.view(np.uint16)
    return v


def _from_numpy(v: "np.ndarray", dtype: str) -> "np.ndarray":
    if dtype == "bfloat16":
        return v.view(ml_dtypes.bfloat16)
    return v


def _flatten(tree) -> Dict[str, Any]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = leaf
    return flat


def save(ckpt_dir: str, step: int, tree, *, async_: bool = False):
    """Save `tree` under <ckpt_dir>/step_<step>. Returns a join() handle."""
    flat = {k: np.asarray(v) for k, v in _flatten(tree).items()}

    def _write():
        final = os.path.join(ckpt_dir, f"step_{step}")
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp, exist_ok=True)
        index = {"step": step, "leaves": {}}
        for k, v in flat.items():
            fname = k.replace("/", "__") + ".npy"
            np.save(os.path.join(tmp, fname), _to_numpy(v))
            index["leaves"][k] = {"file": fname, "shape": list(v.shape),
                                  "dtype": str(v.dtype)}
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)

    if async_:
        t = threading.Thread(target=_write, daemon=True)
        t.start()
        return t
    _write()
    return None


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, step: int, like, shardings=None):
    """Restore into the structure of `like` (a pytree of arrays or
    ShapeDtypeStructs). If `shardings` (matching pytree of NamedShardings)
    is given, leaves are device_put with them — this is the elastic-remesh
    path: restoring onto a different mesh just reshards here."""
    d = os.path.join(ckpt_dir, f"step_{step}")
    with open(os.path.join(d, "index.json")) as f:
        index = json.load(f)
    flat_like = _flatten(like)
    flat_sh = _flatten(shardings) if shardings is not None else {}
    out = {}
    for k in flat_like:
        meta = index["leaves"][k]
        v = _from_numpy(np.load(os.path.join(d, meta["file"])), meta["dtype"])
        sh = flat_sh.get(k)
        out[k] = jax.device_put(v, sh) if sh is not None else v
    # rebuild tree in like's structure
    leaves_like, treedef = jax.tree_util.tree_flatten(like)
    keys = list(_flatten(like).keys())
    return jax.tree_util.tree_unflatten(treedef, [out[k] for k in keys])
