from repro.distributed.sharding import (DEFAULT_RULES, constrain, get_mesh,
                                        global_mesh, sharding_for, spec_for)

__all__ = ["DEFAULT_RULES", "constrain", "get_mesh", "global_mesh",
           "sharding_for", "spec_for"]
