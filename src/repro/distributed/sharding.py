"""Logical-axis sharding (MaxText-style) with divisibility-aware fallback.

Params/activations are annotated with *logical* axis names; a rule table maps
them to physical mesh axes. A dim is sharded only if it divides the mesh axis
size — otherwise it silently replicates (e.g. smollm's 9 heads replicate over
model=16 while its mlp/vocab dims shard). This keeps one rule table valid for
every assigned architecture.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Sequence, Tuple, Union

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axes = Tuple[Optional[str], ...]

# logical axis -> physical mesh axis (or tuple of axes). None = replicate.
DEFAULT_RULES = {
    # parameter axes
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "mlp": "model",
    "experts": "model",
    "ssm_inner": "model",
    "embed": "data",          # FSDP / ZeRO-3 style weight sharding
    "embed_noshard": None,
    "layers": None,
    "blocks": None,
    "inner": None,
    "head_dim": None,
    "ssm_state": None,
    "conv": None,
    # activation axes
    "batch": ("pod", "data"),
    "act_seq": None,
    "kv_seq": "data",         # sequence-parallel KV cache (long-context decode)
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_mlp": "model",
    "act_experts": "model",
    "act_embed": None,
    "act_vocab": "model",
}


# Inference rules (§Perf): FSDP ('embed'->data) is wrong for decode — it
# forces a full weight all-gather over ICI every step (64ms-class for a 27B
# model) where reading the locally-stored shard from HBM costs ~4ms. Params
# replicate over 'data'; MoE expert width picks up the freed 'data' axis so
# mega-MoE (arctic 480B) still stores 477B/256 per device.
INFERENCE_RULES = {**DEFAULT_RULES, "embed": None, "mlp": ("model", "data")}

# Sequence-parallel TP (§Perf, Korthikanti et al.): shard the residual
# stream's sequence dim over 'model' between attention/MLP regions, turning
# per-layer all-reduces into reduce-scatter + all-gather (2x less wire).
SEQ_PARALLEL_RULES = {**DEFAULT_RULES, "act_seq": "model"}


# Serving (shard_map tensor parallelism over a 1-axis 'model' mesh; see
# serving.engine): batch is the engine's slot axis and never shards, the KV
# cache partitions on its head axis only (each shard owns the pages for its
# heads — kv_seq sequence parallelism would split pages mid-stream), and
# weights replicate over everything but 'model' (the INFERENCE_RULES
# argument: FSDP all-gathers are the wrong trade at decode).
SERVING_RULES = {**INFERENCE_RULES,
                 "mlp": "model",
                 "batch": None,
                 "kv_seq": None,
                 "act_seq": None}


def serving_rules(n_model: int, num_heads: int, num_kv_heads: int) -> dict:
    """SERVING_RULES specialized to one model: the head axes shard only if
    *both* ``num_heads`` and ``num_kv_heads`` divide the model-axis size,
    else both replicate.

    Per-leaf divisibility (``spec_for``) is not enough for GQA: it would
    happily shard 16 query heads over model=4 while replicating 9 KV heads,
    and the grouped-attention head mapping (query head ``n`` reads KV head
    ``n // G``) silently pairs the wrong heads when only one side is local.
    Sharding both or neither keeps the local group structure identical to
    the global one (smollm's 9/3 heads replicate over model=2, 4; shard
    over model=3). MLP and vocab dims still fall back per-leaf."""
    heads_ok = (num_heads % n_model == 0) and (num_kv_heads % n_model == 0)
    head_ax = "model" if heads_ok else None
    return {**SERVING_RULES,
            "heads": head_ax, "kv_heads": head_ax,
            "act_heads": head_ax, "act_kv_heads": head_ax}


class _State(threading.local):
    def __init__(self):
        self.mesh: Optional[Mesh] = None
        self.rules: dict = dict(DEFAULT_RULES)


_STATE = _State()


@contextlib.contextmanager
def global_mesh(mesh: Optional[Mesh], rules: Optional[dict] = None):
    """Activate a mesh (+ optional rule overrides) for constrain()/sharding()."""
    prev_mesh, prev_rules = _STATE.mesh, _STATE.rules
    _STATE.mesh = mesh
    if rules is not None:
        _STATE.rules = {**DEFAULT_RULES, **rules}
    try:
        yield
    finally:
        _STATE.mesh, _STATE.rules = prev_mesh, prev_rules


def get_mesh() -> Optional[Mesh]:
    return _STATE.mesh


def _axis_size(mesh: Mesh, phys: Union[str, Tuple[str, ...]]) -> int:
    if isinstance(phys, str):
        phys = (phys,)
    size = 1
    for a in phys:
        size *= mesh.shape[a]
    return size


def spec_for(shape: Sequence[int], axes: Axes, mesh: Mesh,
             rules: Optional[dict] = None) -> P:
    """PartitionSpec for `shape` given logical `axes`, honoring divisibility
    and never using a physical axis twice."""
    rules = rules or _STATE.rules
    used: set = set()
    entries = []
    for dim, name in zip(shape, axes):
        phys = rules.get(name) if name else None
        if phys is None:
            entries.append(None)
            continue
        phys_t = (phys,) if isinstance(phys, str) else tuple(phys)
        # drop already-used axes and axes unknown to this mesh
        phys_t = tuple(a for a in phys_t if a in mesh.shape and a not in used)
        # honor divisibility: greedily drop trailing axes until it divides
        while phys_t and dim % int(np.prod([mesh.shape[a] for a in phys_t])) != 0:
            phys_t = phys_t[:-1]
        if not phys_t:
            entries.append(None)
            continue
        used.update(phys_t)
        entries.append(phys_t[0] if len(phys_t) == 1 else phys_t)
    return P(*entries)


def sharding_for(shape: Sequence[int], axes: Axes,
                 mesh: Optional[Mesh] = None) -> Optional[NamedSharding]:
    mesh = mesh or _STATE.mesh
    if mesh is None:
        return None
    return NamedSharding(mesh, spec_for(shape, axes, mesh))


def constrain(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """with_sharding_constraint if a global mesh is active, else identity."""
    mesh = _STATE.mesh
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, spec_for(x.shape, tuple(axes), mesh)))
