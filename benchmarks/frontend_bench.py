"""Async serving front-end: streaming bit-equality, prefix-aware replica
routing, backpressure, and a fleet-scale traffic replay with SLO accounting.

Three gates (violations raise — the CI smoke for ``serving.frontend``; see
docs/serving.md for the operations guide and docs/benchmarks.md for how to
read the output) plus a reported-not-gated fleet replay:

1. **Streamed bit-equality.** Greedy token streams collected through the
   async front-end (single replica, inline ticks) must be bit-identical to
   the same requests run synchronously through ``ServingEngine.run`` with
   the identical configuration. The front-end adds arrival dynamics,
   streaming, and staging — none of which may change what the model says.
2. **Prefix-aware routing.** On a repeat-observation fleet trace (each
   robot's control loop resubmits its context prefix), the two-replica
   front-end must achieve >= the single-replica prefix-hit page count: the
   router sends a robot's repeats to the replica whose pool holds its
   prefix pages (``KVPool.match_prefix`` over the content-addressed
   digests), so scaling out replicas must not dilute the prefix cache.
3. **Backpressure, not deadlock.** With a tiny ``queue_limit``, flooding
   submits must raise ``Backpressure`` (with a positive ``retry_after_s``)
   for the overflow while every *accepted* request still completes with
   its full token budget.

**Fleet replay (reported).** A Poisson-arrivals x 10 Hz-control-loop x
long-tail-prompt trace (``core.workload.fleet_trace``) is replayed in real
time against the front-end; goodput, client-observed TTFT percentiles, and
control-frequency SLO attainment (action chunk delivered within the
control period) are emitted and written to ``BENCH_frontend.json`` (schema
in docs/benchmarks.md) so the perf trajectory is tracked per-PR —
``perf_compare`` diffs it against a committed baseline when one exists.
Wall-clock figures are machine-dependent and therefore reported, never
gated.
"""
from __future__ import annotations

import asyncio
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.workload import fleet_trace
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.serving import (AsyncFrontend, Backpressure, Request,
                           ServingEngine)

DESCRIPTION = ("Async front-end gates: streamed greedy tokens bit-equal to "
               "the synchronous engine, two-replica prefix-aware routing >= "
               "the single-replica prefix-hit count on a repeat-observation "
               "fleet trace, over-limit submits rejected with retry-after "
               "(not deadlocked); reports goodput / p99 TTFT / 10 Hz "
               "control-SLO attainment from a Poisson fleet replay into "
               "BENCH_frontend.json")

ARCH = "smollm-135m"
MAX_SEQ = 128
PAGE_SIZE = 16
N_SLOTS = 2
CONTROL_HZ = 10.0
BENCH_PATH = os.path.join(os.environ.get("BENCH_DIR", "."),
                          "BENCH_frontend.json")


def _make_engine(cfg, opts, params, **kw):
    kw.setdefault("paged", True)
    kw.setdefault("page_size", PAGE_SIZE)
    kw.setdefault("chunked_prefill", True)
    kw.setdefault("chunk_size", 16)
    kw.setdefault("token_budget", 32)
    return ServingEngine(cfg, opts, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                         eos=-999, fused=True, tick_tokens=4, **kw)


def _gate_bit_equality(cfg, opts, params, emit):
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, cfg.vocab_size, l, dtype=np.int32), m)
            for l, m in [(37, 8), (9, 6), (65, 5), (18, 9), (50, 4)]]
    eng = _make_engine(cfg, opts, params)
    for i, (p, m) in enumerate(reqs):
        eng.submit(Request(uid=i, prompt=p.copy(), max_tokens=m))
    t0 = time.perf_counter()
    base = {r.uid: r.out_tokens for r in eng.run()}
    sync_wall = time.perf_counter() - t0
    assert len(base) == len(reqs), "sync engine dropped requests"

    async def through_frontend():
        async with AsyncFrontend([_make_engine(cfg, opts, params)],
                                 queue_limit=len(reqs) + 1,
                                 offload_ticks=False) as fe:
            streams = [await fe.submit(p, m) for p, m in reqs]
            t0 = time.perf_counter()
            outs = [await s.tokens() for s in streams]
            wall = time.perf_counter() - t0
            await fe.drain()
            return outs, wall, fe

    outs, wall, fe = asyncio.run(through_frontend())
    # frontend uids are assigned in submission order, matching base uids
    assert outs == [base[i] for i in range(len(reqs))], \
        "streamed greedy tokens diverged from the synchronous engine"
    n_tok = sum(len(v) for v in base.values())
    emit("frontend/bit_equal", 1.0,
         f"requests={len(reqs)};tokens={n_tok};replicas=1;inline_ticks=True")
    emit("frontend/stream/decode", wall / n_tok * 1e6,
         f"tok_s={n_tok / wall:.1f};sync_tok_s={n_tok / sync_wall:.1f}")
    return n_tok


def _hit_protocol(fe_engines, trace, queue_limit=64):
    """Submit every robot's episode request, wait for all of them, then
    replay the control repeats; return total prefix-hit pages across the
    replica set. The phase barrier makes the hit count deterministic (a
    repeat can only hit pages that have been written and registered);
    submitting the episodes back-to-back (``submit`` has no internal
    await) makes the warm-phase least-loaded routing a deterministic
    round-robin, so the robots' prefix pages end up spread across the
    replicas and the repeat phase exercises real affinity routing."""

    async def run():
        async with AsyncFrontend(fe_engines, queue_limit=queue_limit,
                                 offload_ticks=False) as fe:
            warm = [await fe.submit(e.prompt, e.max_tokens)
                    for e in trace if e.kind == "episode"]
            for s in warm:
                await s.tokens()
            streams = [await fe.submit(e.prompt, e.max_tokens)
                       for e in trace if e.kind == "control"]
            for s in streams:
                await s.tokens()
            await fe.drain()
            return fe

    fe = asyncio.run(run())
    return sum(eng.stats.prefix_hits for eng in fe_engines), fe


def _gate_routing(cfg, opts, params, emit):
    tail = 4
    trace = fleet_trace(n_robots=4, steps_per_robot=3,
                        control_hz=CONTROL_HZ, ctx_median=40, ctx_sigma=0.4,
                        ctx_max=MAX_SEQ - 16, tail=tail, action_tokens=6,
                        vocab_size=cfg.vocab_size, seed=3)
    n_control = sum(e.kind == "control" for e in trace)
    # a repeat is only *routable* by prefix if its shared context spans at
    # least one full page — shorter contexts legitimately fall back to
    # least-loaded (nothing content-addressed to match)
    routable = sum(e.kind == "control"
                   and (len(e.prompt) - tail) >= PAGE_SIZE for e in trace)
    assert routable >= n_control // 2, \
        f"trace too short-context to exercise routing ({routable} routable)"
    # pools sized so the LRU never reclaims a cached robot prefix mid-test
    hits_single, _ = _hit_protocol(
        [_make_engine(cfg, opts, params, num_pages=96)], trace)
    hits_multi, fe = _hit_protocol(
        [_make_engine(cfg, opts, params, num_pages=96) for _ in range(2)],
        trace)
    assert hits_multi >= hits_single, \
        f"two-replica prefix routing hit {hits_multi} pages < " \
        f"single-replica {hits_single} (router diluting the prefix cache?)"
    assert fe.stats.routed_prefix >= routable, \
        f"only {fe.stats.routed_prefix} of {routable} routable control " \
        f"repeats were routed by prefix affinity"
    emit("frontend/routing/prefix_hits", float(hits_multi),
         f"single_replica={hits_single};replicas=2;"
         f"routed_prefix={fe.stats.routed_prefix};"
         f"routed_load={fe.stats.routed_load};"
         f"control_reqs={n_control};routable={routable}")
    return hits_multi, hits_single


def _gate_backpressure(cfg, opts, params, emit):
    rng = np.random.default_rng(5)
    limit = 3

    async def flood():
        async with AsyncFrontend([_make_engine(cfg, opts, params)],
                                 queue_limit=limit,
                                 offload_ticks=False) as fe:
            streams, rejects, retry = [], 0, 0.0
            for _ in range(limit + 5):
                try:
                    streams.append(await fe.submit(
                        rng.integers(0, cfg.vocab_size, 24, dtype=np.int32),
                        12))
                except Backpressure as exc:
                    rejects += 1
                    retry = exc.retry_after_s
            outs = [await s.tokens() for s in streams]
            await fe.drain()
            return streams, rejects, retry, outs

    streams, rejects, retry, outs = asyncio.run(flood())
    assert rejects > 0, "over-limit submits were queued, not rejected"
    assert retry > 0, "Backpressure carried no retry_after_s estimate"
    assert len(streams) == limit, \
        f"accepted {len(streams)} != queue_limit {limit}"
    assert all(len(o) == 12 for o in outs), \
        "an accepted request did not complete after backpressure engaged"
    emit("frontend/backpressure", float(rejects),
         f"limit={limit};accepted={len(streams)};"
         f"retry_after_s={retry:.4f};accepted_all_completed=True")


def _fleet_replay(cfg, opts, params, emit):
    """Real-time replay of a Poisson x 10 Hz x long-tail trace on two
    replicas; returns the report dict (reported, never gated: wall clock)."""
    trace = fleet_trace(n_robots=6, steps_per_robot=4,
                        control_hz=CONTROL_HZ, arrival_rate=4.0,
                        ctx_median=32, ctx_sigma=0.6, ctx_max=MAX_SEQ - 16,
                        tail=4, action_tokens=8, vocab_size=cfg.vocab_size,
                        seed=11)

    async def replay():
        engines = [_make_engine(cfg, opts, params) for _ in range(2)]
        async with AsyncFrontend(engines, queue_limit=16) as fe:
            t0 = time.perf_counter()
            results = []        # (event, stream | None)
            for e in trace:
                delay = e.t - (time.perf_counter() - t0)
                if delay > 0:
                    await asyncio.sleep(delay)
                try:
                    results.append((e, await fe.submit(e.prompt,
                                                       e.max_tokens)))
                except Backpressure as exc:
                    # fleet clients back off and drop the stale observation
                    # (a control step re-sent after its period is useless)
                    results.append((e, None))
                    await asyncio.sleep(min(exc.retry_after_s, 0.05))
            for _, s in results:
                if s is not None:
                    await s.tokens()
            await fe.drain()
            wall = time.perf_counter() - t0
            return results, wall, fe, engines

    results, wall, fe, engines = asyncio.run(replay())
    served = [(e, s) for e, s in results if s is not None]
    n_tok = sum(len(s.request.out_tokens) for _, s in served)
    slo_met = [s.t_done - s.t_submit <= e.deadline_s for e, s in served]
    control = [(e, s) for e, s in served if e.kind == "control"]
    control_met = sum(s.t_done - s.t_submit <= e.deadline_s
                      for e, s in control)
    rep = fe.stats.report()
    report = {
        "bench": "frontend",
        "schema": 1,
        "arch": ARCH,
        "replicas": len(engines),
        "control_hz": CONTROL_HZ,
        "n_requests": len(trace),
        "n_served": len(served),
        "n_rejected": fe.stats.rejected,
        "wall_s": wall,
        "goodput_rps": sum(slo_met) / wall,
        "goodput_tok_s": n_tok / wall,
        "slo_attainment": (sum(slo_met) / len(served)) if served else 0.0,
        "control_slo_attainment": (control_met / len(control)
                                   if control else 0.0),
        "ttft_p50_s": rep.get("ttft_p50_s", 0.0),
        "ttft_p99_s": rep.get("ttft_p99_s", 0.0),
        "latency_p99_s": rep.get("latency_p99_s", 0.0),
        "prefix_hits": sum(eng.stats.prefix_hits for eng in engines),
        "routed_prefix": fe.stats.routed_prefix,
    }
    emit("frontend/fleet/goodput", report["goodput_rps"],
         f"tok_s={report['goodput_tok_s']:.1f};served={len(served)}"
         f"/{len(trace)};rejected={report['n_rejected']};"
         f"reported_not_gated=True")
    emit("frontend/fleet/ttft_p99", report["ttft_p99_s"] * 1e6,
         f"p50={report['ttft_p50_s'] * 1e6:.0f}us;"
         f"latency_p99={report['latency_p99_s'] * 1e6:.0f}us;"
         f"reported_not_gated=True")
    emit("frontend/fleet/slo_attainment", report["slo_attainment"],
         f"control={report['control_slo_attainment']:.3f};"
         f"hz={CONTROL_HZ};reported_not_gated=True")
    return report


def run(emit):
    cfg = get_config(ARCH).reduced()
    opts = ModelOptions(remat=False)
    params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0),
                           jnp.float32)

    n_tok = _gate_bit_equality(cfg, opts, params, emit)
    hits_multi, hits_single = _gate_routing(cfg, opts, params, emit)
    _gate_backpressure(cfg, opts, params, emit)
    report = _fleet_replay(cfg, opts, params, emit)

    report["bit_equal"] = True
    report["routing_prefix_hits"] = hits_multi
    report["routing_single_replica_hits"] = hits_single
    with open(BENCH_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("frontend/bench_json", float(report["n_served"]),
         f"path={BENCH_PATH};schema=1")
