"""Async serving front-end: streaming bit-equality, prefix-aware replica
routing, backpressure, and a fleet-scale traffic replay with SLO accounting.

Four gates (violations raise — the CI smoke for ``serving.frontend``; see
docs/serving.md for the operations guide and docs/benchmarks.md for how to
read the output) plus a reported-not-gated fleet replay:

1. **Streamed bit-equality.** Greedy token streams collected through the
   async front-end (single replica, inline ticks) must be bit-identical to
   the same requests run synchronously through ``ServingEngine.run`` with
   the identical configuration. The front-end adds arrival dynamics,
   streaming, and staging — none of which may change what the model says.
2. **Prefix-aware routing.** On a repeat-observation fleet trace (each
   robot's control loop resubmits its context prefix), the two-replica
   front-end must achieve >= the single-replica prefix-hit page count: the
   router sends a robot's repeats to the replica whose pool holds its
   prefix pages (``KVPool.match_prefix`` over the content-addressed
   digests), so scaling out replicas must not dilute the prefix cache.
3. **Backpressure, not deadlock.** With a tiny ``queue_limit``, flooding
   submits must raise ``Backpressure`` (with a positive ``retry_after_s``)
   for the overflow while every *accepted* request still completes with
   its full token budget.
4. **SLO scheduling beats static.** On a seeded mixed trace — realtime
   control requests arriving behind a best-effort long-prompt backlog,
   deadlines denominated in *measured tick time* so the gate is robust to
   machine speed — the deadline-aware scheduler (``slo_hz`` + priority
   classes) must hit >= 0.9 control-deadline attainment and strictly beat
   the static FCFS baseline (the same requests submitted classless, which
   reproduces the pre-SLO scheduler bit for bit). An all-best-effort
   request set must produce greedy streams bit-identical between the
   ``slo_hz``-enabled and static engines: with no deadline pressure the
   SLO controller must be a no-op.

**Fleet replay (reported).** A Poisson-arrivals x 10 Hz-control-loop x
long-tail-prompt trace (``core.workload.fleet_trace``) is replayed in real
time against the front-end; goodput, client-observed TTFT percentiles, and
control-frequency SLO attainment (action chunk delivered within the
control period) are emitted and written to ``BENCH_frontend.json`` (schema
in docs/benchmarks.md) so the perf trajectory is tracked per-PR —
``perf_compare`` diffs it against a committed baseline when one exists.
Wall-clock figures are machine-dependent and therefore reported, never
gated.
"""
from __future__ import annotations

import asyncio
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.core.workload import fleet_trace
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.serving import (AsyncFrontend, Backpressure, Request,
                           ServingEngine)

DESCRIPTION = ("Async front-end gates: streamed greedy tokens bit-equal to "
               "the synchronous engine, two-replica prefix-aware routing >= "
               "the single-replica prefix-hit count on a repeat-observation "
               "fleet trace, over-limit submits rejected with retry-after "
               "(not deadlocked), SLO scheduler >= 0.9 control-deadline "
               "attainment and strictly above the static baseline on a "
               "mixed trace (bit-equal when no deadline pressure); reports "
               "goodput / p99 TTFT / 10 Hz control-SLO attainment from a "
               "Poisson fleet replay into BENCH_frontend.json")

ARCH = "smollm-135m"
MAX_SEQ = 128
PAGE_SIZE = 16
N_SLOTS = 2
CONTROL_HZ = 10.0
BENCH_PATH = os.path.join(os.environ.get("BENCH_DIR", "."),
                          "BENCH_frontend.json")


def _make_engine(cfg, opts, params, **kw):
    kw.setdefault("paged", True)
    kw.setdefault("page_size", PAGE_SIZE)
    kw.setdefault("chunked_prefill", True)
    kw.setdefault("chunk_size", 16)
    kw.setdefault("token_budget", 32)
    return ServingEngine(cfg, opts, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                         eos=-999, fused=True, tick_tokens=4, **kw)


def _gate_bit_equality(cfg, opts, params, emit):
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, cfg.vocab_size, l, dtype=np.int32), m)
            for l, m in [(37, 8), (9, 6), (65, 5), (18, 9), (50, 4)]]
    eng = _make_engine(cfg, opts, params)
    for i, (p, m) in enumerate(reqs):
        eng.submit(Request(uid=i, prompt=p.copy(), max_tokens=m))
    t0 = time.perf_counter()
    base = {r.uid: r.out_tokens for r in eng.run()}
    sync_wall = time.perf_counter() - t0
    assert len(base) == len(reqs), "sync engine dropped requests"

    async def through_frontend():
        async with AsyncFrontend([_make_engine(cfg, opts, params)],
                                 queue_limit=len(reqs) + 1,
                                 offload_ticks=False) as fe:
            streams = [await fe.submit(p, m) for p, m in reqs]
            t0 = time.perf_counter()
            outs = [await s.tokens() for s in streams]
            wall = time.perf_counter() - t0
            await fe.drain()
            return outs, wall, fe

    outs, wall, fe = asyncio.run(through_frontend())
    # frontend uids are assigned in submission order, matching base uids
    assert outs == [base[i] for i in range(len(reqs))], \
        "streamed greedy tokens diverged from the synchronous engine"
    n_tok = sum(len(v) for v in base.values())
    emit("frontend/bit_equal", 1.0,
         f"requests={len(reqs)};tokens={n_tok};replicas=1;inline_ticks=True")
    emit("frontend/stream/decode", wall / n_tok * 1e6,
         f"tok_s={n_tok / wall:.1f};sync_tok_s={n_tok / sync_wall:.1f}")
    return n_tok


def _hit_protocol(fe_engines, trace, queue_limit=64):
    """Submit every robot's episode request, wait for all of them, then
    replay the control repeats; return total prefix-hit pages across the
    replica set. The phase barrier makes the hit count deterministic (a
    repeat can only hit pages that have been written and registered);
    submitting the episodes back-to-back (``submit`` has no internal
    await) makes the warm-phase least-loaded routing a deterministic
    round-robin, so the robots' prefix pages end up spread across the
    replicas and the repeat phase exercises real affinity routing."""

    async def run():
        async with AsyncFrontend(fe_engines, queue_limit=queue_limit,
                                 offload_ticks=False) as fe:
            warm = [await fe.submit(e.prompt, e.max_tokens)
                    for e in trace if e.kind == "episode"]
            for s in warm:
                await s.tokens()
            streams = [await fe.submit(e.prompt, e.max_tokens)
                       for e in trace if e.kind == "control"]
            for s in streams:
                await s.tokens()
            await fe.drain()
            return fe

    fe = asyncio.run(run())
    return sum(eng.stats.prefix_hits for eng in fe_engines), fe


def _gate_routing(cfg, opts, params, emit):
    tail = 4
    trace = fleet_trace(n_robots=4, steps_per_robot=3,
                        control_hz=CONTROL_HZ, ctx_median=40, ctx_sigma=0.4,
                        ctx_max=MAX_SEQ - 16, tail=tail, action_tokens=6,
                        vocab_size=cfg.vocab_size, seed=3)
    n_control = sum(e.kind == "control" for e in trace)
    # a repeat is only *routable* by prefix if its shared context spans at
    # least one full page — shorter contexts legitimately fall back to
    # least-loaded (nothing content-addressed to match)
    routable = sum(e.kind == "control"
                   and (len(e.prompt) - tail) >= PAGE_SIZE for e in trace)
    assert routable >= n_control // 2, \
        f"trace too short-context to exercise routing ({routable} routable)"
    # pools sized so the LRU never reclaims a cached robot prefix mid-test
    hits_single, _ = _hit_protocol(
        [_make_engine(cfg, opts, params, num_pages=96)], trace)
    hits_multi, fe = _hit_protocol(
        [_make_engine(cfg, opts, params, num_pages=96) for _ in range(2)],
        trace)
    assert hits_multi >= hits_single, \
        f"two-replica prefix routing hit {hits_multi} pages < " \
        f"single-replica {hits_single} (router diluting the prefix cache?)"
    assert fe.stats.routed_prefix >= routable, \
        f"only {fe.stats.routed_prefix} of {routable} routable control " \
        f"repeats were routed by prefix affinity"
    emit("frontend/routing/prefix_hits", float(hits_multi),
         f"single_replica={hits_single};replicas=2;"
         f"routed_prefix={fe.stats.routed_prefix};"
         f"routed_load={fe.stats.routed_load};"
         f"control_reqs={n_control};routable={routable}")
    return hits_multi, hits_single


def _gate_backpressure(cfg, opts, params, emit):
    rng = np.random.default_rng(5)
    limit = 3

    async def flood():
        async with AsyncFrontend([_make_engine(cfg, opts, params)],
                                 queue_limit=limit,
                                 offload_ticks=False) as fe:
            streams, rejects, retry = [], 0, 0.0
            for _ in range(limit + 5):
                try:
                    streams.append(await fe.submit(
                        rng.integers(0, cfg.vocab_size, 24, dtype=np.int32),
                        12))
                except Backpressure as exc:
                    rejects += 1
                    retry = exc.retry_after_s
            outs = [await s.tokens() for s in streams]
            await fe.drain()
            return streams, rejects, retry, outs

    streams, rejects, retry, outs = asyncio.run(flood())
    assert rejects > 0, "over-limit submits were queued, not rejected"
    assert retry > 0, "Backpressure carried no retry_after_s estimate"
    assert len(streams) == limit, \
        f"accepted {len(streams)} != queue_limit {limit}"
    assert all(len(o) == 12 for o in outs), \
        "an accepted request did not complete after backpressure engaged"
    emit("frontend/backpressure", float(rejects),
         f"limit={limit};accepted={len(streams)};"
         f"retry_after_s={retry:.4f};accepted_all_completed=True")


def _gate_slo(cfg, opts, params, emit):
    """Deadline-aware scheduling must buy real attainment on mixed traffic
    and cost nothing on uniform traffic.

    The trace: ten 96-token best-effort prompts flood the queue, then four
    short realtime control requests arrive behind them. Deadlines are set
    to 15x the *measured median* tick wall (calibrated on a warmed engine
    of the same config; the median resists compile-tick outliers), and
    each measured engine's dispatch path is warmed with a throwaway
    request first, so client latencies are tick-proportional rather than
    first-dispatch artifacts. The contrast is then structural, not a wall
    clock bet: the SLO engine admits the controls class-first and finishes
    them in a handful of ticks, while the static FCFS baseline makes them
    wait out the whole backlog (~35 ticks of prefill+decode)."""
    rng = np.random.default_rng(7)
    be_prompts = [rng.integers(0, cfg.vocab_size, 96, dtype=np.int32)
                  for _ in range(10)]
    rt_prompts = [rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)
                  for _ in range(4)]
    cal = np.random.default_rng(8)
    warm_prompt = cal.integers(0, cfg.vocab_size, 32, dtype=np.int32)

    # calibrate: the first run eats jit compilation for both trace shapes
    # (a backlog-length and a control-length prompt together, so no
    # compile lands inside a measured run later); the second runs two
    # concurrent backlog-shaped requests — both slots chunking and
    # decoding, the per-tick work the mixed trace sustains — and its
    # median tick sets the deadline scale for this machine
    warm = _make_engine(cfg, opts, params, slo_hz=CONTROL_HZ)
    warm.submit(Request(uid=0, prompt=be_prompts[0].copy(), max_tokens=8))
    warm.submit(Request(uid=1, prompt=rt_prompts[0].copy(), max_tokens=4))
    warm.run()
    n_cold = len(warm.stats.tick_s)
    for uid in (2, 3):
        warm.submit(Request(
            uid=uid, prompt=cal.integers(0, cfg.vocab_size, 96,
                                         dtype=np.int32), max_tokens=8))
    warm.run()
    ticks = sorted(warm.stats.tick_s[n_cold:])
    tick_est = ticks[len(ticks) // 2] if ticks else 1e-3
    deadline = 15.0 * tick_est

    def run_mixed(slo_hz, control_class):
        eng = _make_engine(cfg, opts, params, slo_hz=slo_hz)
        eng.submit(Request(uid=1000, prompt=warm_prompt.copy(),
                           max_tokens=4))     # warm this engine's dispatch
        eng.run()
        uid = 0
        for p in be_prompts:
            eng.submit(Request(uid=uid, prompt=p.copy(), max_tokens=8))
            uid += 1
        rt_uids = []
        for p in rt_prompts:
            eng.submit(Request(uid=uid, prompt=p.copy(), max_tokens=4,
                               priority=control_class, deadline_s=deadline))
            rt_uids.append(uid)
            uid += 1
        done = {r.uid: r for r in eng.run()}
        assert all(u in done for u in range(uid)), \
            "mixed-trace engine dropped requests"
        met = sum(done[u].t_done <= done[u].t_deadline for u in rt_uids)
        return met / len(rt_uids), eng

    slo_att, slo_eng = run_mixed(CONTROL_HZ, "realtime")
    static_att, _ = run_mixed(0.0, "best_effort")
    rep = slo_eng.stats.phase_report()
    assert rep.get("deadline_total_realtime") == len(rt_prompts), \
        "engine deadline scoreboard did not count the control requests"
    assert abs(rep.get("deadline_attainment_realtime", -1.0)
               - slo_att) < 1e-9, \
        "engine-side attainment disagrees with client-side measurement"
    assert slo_att >= 0.9, \
        f"SLO scheduler control attainment {slo_att:.2f} < 0.9 " \
        f"(deadline={deadline * 1e3:.1f}ms = 15 ticks)"
    assert slo_att > static_att, \
        f"SLO scheduler ({slo_att:.2f}) did not beat the static FCFS " \
        f"baseline ({static_att:.2f}) on the same seeded trace"

    # no-pressure bit-equality: all-best-effort, no deadlines — the SLO
    # engine must schedule (and therefore generate) identically to static
    plain = [(rng.integers(0, cfg.vocab_size, l, dtype=np.int32), m)
             for l, m in [(21, 6), (44, 5), (9, 7), (60, 4)]]

    def run_plain(slo_hz):
        eng = _make_engine(cfg, opts, params, slo_hz=slo_hz)
        for i, (p, m) in enumerate(plain):
            eng.submit(Request(uid=i, prompt=p.copy(), max_tokens=m))
        return {r.uid: r.out_tokens for r in eng.run()}

    assert run_plain(CONTROL_HZ) == run_plain(0.0), \
        "slo_hz engine diverged from static on an all-best-effort workload"
    emit("frontend/slo/attainment", slo_att,
         f"static={static_att:.3f};deadline_ticks=15;"
         f"tick_est_us={tick_est * 1e6:.0f};controls={len(rt_prompts)};"
         f"backlog={len(be_prompts)};no_pressure_bit_equal=True")
    return slo_att, static_att


def _fleet_replay(cfg, opts, params, emit):
    """Real-time replay of a Poisson x 10 Hz x long-tail trace on two
    replicas; returns the report dict (reported, never gated: wall clock)."""
    trace = fleet_trace(n_robots=6, steps_per_robot=4,
                        control_hz=CONTROL_HZ, arrival_rate=4.0,
                        ctx_median=32, ctx_sigma=0.6, ctx_max=MAX_SEQ - 16,
                        tail=4, action_tokens=8, vocab_size=cfg.vocab_size,
                        seed=11)

    async def replay():
        engines = [_make_engine(cfg, opts, params, slo_hz=CONTROL_HZ)
                   for _ in range(2)]
        async with AsyncFrontend(engines, queue_limit=16) as fe:
            t0 = time.perf_counter()
            results = []        # (event, stream | None)
            for e in trace:
                delay = e.t - (time.perf_counter() - t0)
                if delay > 0:
                    await asyncio.sleep(delay)
                try:
                    results.append((e, await fe.submit(
                        e.prompt, e.max_tokens, priority=e.priority,
                        deadline_s=e.deadline_s)))
                except Backpressure as exc:
                    # fleet clients back off for the server's own estimate
                    # (per-replica tick EWMA x queue depth) and drop the
                    # stale observation — a control step re-sent after its
                    # period is useless
                    results.append((e, None))
                    await asyncio.sleep(exc.retry_after_s)
            for _, s in results:
                if s is not None:
                    await s.tokens()
            await fe.drain()
            wall = time.perf_counter() - t0
            return results, wall, fe, engines

    results, wall, fe, engines = asyncio.run(replay())
    served = [(e, s) for e, s in results if s is not None]
    n_tok = sum(len(s.request.out_tokens) for _, s in served)
    slo_met = [s.t_done - s.t_submit <= e.deadline_s for e, s in served]
    control = [(e, s) for e, s in served if e.kind == "control"]
    control_met = sum(s.t_done - s.t_submit <= e.deadline_s
                      for e, s in control)
    rep = fe.stats.report()
    report = {
        "bench": "frontend",
        "schema": 1,
        "arch": ARCH,
        "replicas": len(engines),
        "control_hz": CONTROL_HZ,
        "n_requests": len(trace),
        "n_served": len(served),
        "n_rejected": fe.stats.rejected,
        "wall_s": wall,
        "goodput_rps": sum(slo_met) / wall,
        "goodput_tok_s": n_tok / wall,
        "slo_attainment": (sum(slo_met) / len(served)) if served else 0.0,
        "control_slo_attainment": (control_met / len(control)
                                   if control else 0.0),
        "ttft_p50_s": rep.get("ttft_p50_s", 0.0),
        "ttft_p99_s": rep.get("ttft_p99_s", 0.0),
        "latency_p99_s": rep.get("latency_p99_s", 0.0),
        "prefix_hits": sum(eng.stats.prefix_hits for eng in engines),
        "routed_prefix": fe.stats.routed_prefix,
        "slo_hz": CONTROL_HZ,
        "preemptions": sum(
            sum(eng.stats.preemptions.values()) for eng in engines),
    }
    emit("frontend/fleet/goodput", report["goodput_rps"],
         f"tok_s={report['goodput_tok_s']:.1f};served={len(served)}"
         f"/{len(trace)};rejected={report['n_rejected']};"
         f"reported_not_gated=True")
    emit("frontend/fleet/ttft_p99", report["ttft_p99_s"] * 1e6,
         f"p50={report['ttft_p50_s'] * 1e6:.0f}us;"
         f"latency_p99={report['latency_p99_s'] * 1e6:.0f}us;"
         f"reported_not_gated=True")
    emit("frontend/fleet/slo_attainment", report["slo_attainment"],
         f"control={report['control_slo_attainment']:.3f};"
         f"hz={CONTROL_HZ};reported_not_gated=True")
    return report


def run(emit):
    cfg = get_config(ARCH).reduced()
    opts = ModelOptions(remat=False)
    params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0),
                           jnp.float32)

    n_tok = _gate_bit_equality(cfg, opts, params, emit)
    hits_multi, hits_single = _gate_routing(cfg, opts, params, emit)
    _gate_backpressure(cfg, opts, params, emit)
    slo_att, static_att = _gate_slo(cfg, opts, params, emit)
    report = _fleet_replay(cfg, opts, params, emit)

    report["bit_equal"] = True
    report["routing_prefix_hits"] = hits_multi
    report["routing_single_replica_hits"] = hits_single
    report["slo_gate_attainment"] = slo_att
    report["slo_gate_static_attainment"] = static_att
    with open(BENCH_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("frontend/bench_json", float(report["n_served"]),
         f"path={BENCH_PATH};schema=1")
