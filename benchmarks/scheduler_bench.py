"""Chunked-prefill scheduler: bit-exactness, prefix-skip compute, banded
key-lane work, and decode-tick latency under mixed arrivals.

Four gates (violations raise; this is the CI smoke for the scheduler
subsystem — see docs/scheduler.md for the tick anatomy and
docs/benchmarks.md for how to read the output):

1. **Bit-equality across chunkings.** Greedy token streams from the chunked
   engine must be bit-identical to the monolithic admit-stall baseline for
   chunk sizes {16, 64, full}, on both the dense and the paged layout. This
   is the prefill-from-position contract under the banded chunk core: every
   serving prefill path scans the same absolute key-block partition with an
   online softmax whose fully-masked block updates are exact no-ops, so
   neither *how* a prompt is chunked nor *how much* cache view a dispatch
   sees can ever change what the model says.
2. **Prefix-hit compute skip.** Repeated prompts (the serving pattern for
   repeated robot observations) must *skip* the shared fraction of prefill:
   ``EngineStats.prefill_tokens + prefill_skipped == total prompt
   positions`` and the skipped count covers >= the shared full pages of
   every repeat — while the streams still match the no-cache baseline
   bit-for-bit (the skipped pages' KV is read, not recomputed).
3. **Head-of-line blocking under mixed arrivals.** With a long prompt
   arriving while short requests decode: (a) *structural* — the baseline
   must pay the whole prompt inside one tick while no scheduler tick may
   prefill more than the token budget (``tick_prefill_tokens``,
   deterministic on any machine); (b) *wall clock* — chunked p99 tick
   latency <= 0.8x the baseline's p99 (warm jit caches, interleaved
   best-of rounds, retried before failing so a loaded dev box doesn't
   flake what a quiet CI runner measures cleanly).
4. **Banded key-lane work.** For a prompt of ``max_seq / 8``, prefill
   attention key-axis work (``EngineStats.prefill_key_lanes``: rows x
   banded live-prefix length actually attended) must come in <= 0.25x the
   old full-view core's rows x ``max_seq`` figure
   (``prefill_key_lanes_full``) — on both engines and both layouts. The
   counter is structural (host-side accounting of what each dispatch
   attends), so the gate is deterministic; the banded-vs-full-view core
   wall clock is *reported* alongside, not gated (CPU timing noise).

Reported rows: per-configuration tokens/s, prefill-token accounting, TTFT /
queue means, key-lane ratios, and tick-latency percentiles.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import layers as L
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.serving import Request, ServingEngine

DESCRIPTION = ("Chunked-prefill scheduler gates: greedy streams bit-identical "
               "to monolithic prefill for chunk sizes {16,64,full} (dense + "
               "paged), prefix hits skip >= the shared fraction of prefill "
               "tokens, banded prefill key-lane work <= 0.25x the full-view "
               "core for a max_seq/8 prompt, and p99 tick latency under "
               "mixed arrivals <= 0.8x the admit-stall baseline")

ARCH = "smollm-135m"
PAGE_SIZE = 16
MAX_SEQ = 256
N_SLOTS = 2
LONG_PROMPT = 240           # the head-of-line blocker for gate 3
TOKEN_BUDGET = 48
P99_RATIO = 0.8


def _make_engine(cfg, opts, params, **kw):
    kw.setdefault("tick_tokens", 4)
    return ServingEngine(cfg, opts, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                         eos=-999, fused=True, **kw)


def _run(cfg, opts, params, reqs, **kw):
    eng = _make_engine(cfg, opts, params, **kw)
    for i, (p, m) in enumerate(reqs):
        eng.submit(Request(uid=i, prompt=p.copy(), max_tokens=m))
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    assert len(done) == len(reqs), "engine dropped requests"
    return {r.uid: r.out_tokens for r in done}, eng, wall


def run(emit):
    cfg = get_config(ARCH).reduced()
    opts = ModelOptions(remat=False)
    params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    rng = np.random.default_rng(0)

    # mixed prompt lengths, none dividing the chunk sizes evenly
    reqs = [(rng.integers(0, cfg.vocab_size, l, dtype=np.int32), m)
            for l, m in [(37, 8), (9, 6), (65, 5), (18, 9), (50, 4)]]
    total_prompt = sum(len(p) for p, _ in reqs)

    # -- gate 1: bit-equality across chunk sizes and layouts ---------------
    base, eng_b, wall = _run(cfg, opts, params, reqs)
    n_tok = sum(len(v) for v in base.values())
    emit("scheduler/monolithic/decode", wall / n_tok * 1e6,
         f"tok_s={n_tok / wall:.1f}")
    for chunk in (16, 64, MAX_SEQ):
        for paged in (False, True):
            toks, eng, wall = _run(
                cfg, opts, params, reqs, chunked_prefill=True,
                chunk_size=chunk, token_budget=max(TOKEN_BUDGET, chunk),
                paged=paged, page_size=PAGE_SIZE)
            tag = f"chunk{chunk}_{'paged' if paged else 'dense'}"
            assert toks == base, \
                f"{tag}: chunked greedy streams diverged from monolithic"
            assert eng.stats.prefill_tokens == total_prompt, \
                f"{tag}: prefill token accounting off " \
                f"({eng.stats.prefill_tokens} != {total_prompt})"
            emit(f"scheduler/{tag}/decode", wall / n_tok * 1e6,
                 f"tok_s={n_tok / wall:.1f};bit_equal=True;"
                 f"prefill_tokens={eng.stats.prefill_tokens}")
    emit("scheduler/bit_equal", 1.0,
         "chunk_sizes=16,64,full;layouts=dense,paged")

    # -- gate 2: prefix hits skip recomputation ----------------------------
    shared = rng.integers(0, cfg.vocab_size, 64, dtype=np.int32)
    rep_reqs = [(shared, 6),
                (rng.integers(0, cfg.vocab_size, 33, dtype=np.int32), 8),
                (shared, 5),
                (shared, 7)]
    rep_total = sum(len(p) for p, _ in rep_reqs)
    rep_base, _, _ = _run(cfg, opts, params, rep_reqs)
    toks, eng, _ = _run(cfg, opts, params, rep_reqs, chunked_prefill=True,
                        chunk_size=16, token_budget=TOKEN_BUDGET,
                        paged=True, page_size=PAGE_SIZE)
    assert toks == rep_base, \
        "prefix-skip streams diverged from the no-cache baseline"
    st = eng.stats
    assert st.prefill_tokens + st.prefill_skipped == rep_total, \
        f"prefill accounting: {st.prefill_tokens} run + " \
        f"{st.prefill_skipped} skipped != {rep_total} prompt positions"
    # each repeat shares every full page short of the prompt end; the skip
    # is capped one page early so the last-token logits are computed
    shared_pages = (len(shared) - 1) // PAGE_SIZE
    min_skip = 2 * shared_pages * PAGE_SIZE
    assert st.prefill_skipped >= min_skip, \
        f"prefix hits skipped only {st.prefill_skipped} prefill tokens " \
        f"(shared fraction is >= {min_skip})"
    frac = st.prefill_skipped / rep_total
    emit("scheduler/prefix_skip/tokens", float(st.prefill_skipped),
         f"total={rep_total};frac={frac:.3f};min={min_skip};"
         f"prefix_hits={st.prefix_hits};bit_equal=True")

    # -- gate 4: banded key-lane work --------------------------------------
    # Runs before the wall-clock gate 3 so a timing flake on a loaded
    # box cannot mask this deterministic signal. A max_seq/8 prompt
    # must attend <= 0.25x the key lanes of the old
    # full-view core — structural, via the EngineStats key-lane counters
    # (rows x banded live-prefix length vs rows x max_seq), on both engines
    # and both layouts.
    short = MAX_SEQ // 8
    kl_reqs = [(rng.integers(0, cfg.vocab_size, short, dtype=np.int32), 6)]
    for tag, kw in (("mono_dense", {}),
                    ("mono_paged", dict(paged=True, page_size=PAGE_SIZE)),
                    ("chunk_dense", dict(chunked_prefill=True, chunk_size=16,
                                         token_budget=TOKEN_BUDGET)),
                    ("chunk_paged", dict(chunked_prefill=True, chunk_size=16,
                                         token_budget=TOKEN_BUDGET,
                                         paged=True, page_size=PAGE_SIZE))):
        _, eng, _ = _run(cfg, opts, params, kl_reqs, **kw)
        st = eng.stats
        ratio = st.prefill_key_lanes / st.prefill_key_lanes_full
        assert ratio <= 0.25, \
            f"{tag}: banded prefill key-lane ratio {ratio:.3f} > 0.25 for " \
            f"a {short}-token prompt (banded core not engaged?)"
        # the per-tick breakdown must account for every attended lane
        assert sum(st.tick_key_lanes) == st.prefill_key_lanes, \
            f"{tag}: tick_key_lanes {sum(st.tick_key_lanes)} != total " \
            f"{st.prefill_key_lanes}"
        busy = [t for t in st.tick_key_lanes if t]
        emit(f"scheduler/band/{tag}", ratio,
             f"lanes={st.prefill_key_lanes};"
             f"full={st.prefill_key_lanes_full};gate<=0.25;"
             f"band={opts.prefill_band};"
             f"ticks_with_prefill={len(busy)};"
             f"max_tick_lanes={max(busy) if busy else 0}")
    # reported (not gated): one chunk dispatch through the banded core vs
    # the old full-max_seq-view dense core — CPU wall clock is noisy, the
    # structural counter above is the gate
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    S, N, K, h = 16, 8, 2, 64
    q = jax.random.normal(ks[0], (1, S, N, h))
    kc = jax.random.normal(ks[1], (1, MAX_SEQ, K, h))
    vc = jax.random.normal(ks[2], (1, MAX_SEQ, K, h))
    idx = jnp.asarray([short - S], jnp.int32)
    band = opts.prefill_band
    Lb = L.band_len(short, band, MAX_SEQ)
    cores = {
        "banded": jax.jit(lambda q, k, v: L.attention_chunk_banded(
            q, k[:, :Lb], v[:, :Lb], idx, 0, band)),
        "full_view": jax.jit(lambda q, k, v: L.attention_dense(
            q, k, v, idx[0] + jnp.arange(S), jnp.arange(MAX_SEQ), 0)),
    }
    for name, f in cores.items():
        f(q, kc, vc).block_until_ready()          # warm the jit cache
        t0 = time.perf_counter()
        for _ in range(20):
            out = f(q, kc, vc)
        out.block_until_ready()
        dt = (time.perf_counter() - t0) / 20
        emit(f"scheduler/band/core_{name}", dt * 1e6,
             f"S={S};key_lanes={Lb if name == 'banded' else MAX_SEQ};"
             f"reported_not_gated=True")

    # -- gate 3: p99 tick latency under mixed arrivals ---------------------
    # short decode-heavy requests + one long prompt landing behind them: the
    # admit-stall baseline pays the whole LONG_PROMPT prefill inside one
    # tick; the scheduler spreads it across ticks under the token budget.
    # tick_tokens=1 keeps the decode stage identical (and small) on both
    # sides so the tick-latency difference is the prefill policy, not the
    # fused-tick depth; best-of-3 p99 de-noises shared CPU.
    mix_reqs = [(rng.integers(0, cfg.vocab_size, 8, dtype=np.int32), 24),
                (rng.integers(0, cfg.vocab_size, 12, dtype=np.int32), 24),
                (rng.integers(0, cfg.vocab_size, LONG_PROMPT,
                              dtype=np.int32), 8),
                (rng.integers(0, cfg.vocab_size, 10, dtype=np.int32), 16)]
    # budget 18 = one 16-token chunk + the two decode slots' reservation:
    # a scheduler tick never carries more than one prefill dispatch, so the
    # worst tick stays near the median and the contrast with the baseline's
    # whole-prompt tick is structural, not a timing accident
    stall_kw = dict(tick_tokens=1)
    chunk_kw = dict(tick_tokens=1, chunked_prefill=True, chunk_size=16,
                    token_budget=18, paged=True, page_size=PAGE_SIZE)
    mix_base, eng_b, _ = _run(cfg, opts, params, mix_reqs, **stall_kw)  # warm
    mix_chunk, eng_c, _ = _run(cfg, opts, params, mix_reqs, **chunk_kw)
    assert mix_chunk == mix_base, "mixed-arrival streams diverged"
    # 3a (structural, deterministic): the head-of-line blocker itself. The
    # admit-stall baseline must pay the whole LONG_PROMPT inside one tick;
    # no scheduler tick may prefill more than the token budget. This is the
    # *cause* of the latency tail and is load-independent.
    stall_max = max(eng_b.stats.tick_prefill_tokens)
    sched_max = max(eng_c.stats.tick_prefill_tokens)
    assert stall_max >= LONG_PROMPT, \
        f"baseline should pay the {LONG_PROMPT}-token prompt (plus any " \
        f"co-admitted short one) in one tick, saw {stall_max}"
    assert sched_max <= chunk_kw["token_budget"], \
        f"a scheduler tick prefilled {sched_max} tokens (> budget " \
        f"{chunk_kw['token_budget']})"
    emit("scheduler/tick_prefill_max", float(sched_max),
         f"stall_max={stall_max};budget={chunk_kw['token_budget']};"
         f"ratio={sched_max / stall_max:.3f}")
    # 3b (wall clock): interleaved rounds + per-engine min de-noise
    # transient co-tenants; a saturated machine can still drown the ~10ms
    # signal, so the measurement is retried before failing (CI is serial
    # and quiet — retries are for shared dev boxes).
    for attempt in range(3):
        engines = {}
        vals = {"stall": [], "sched": []}
        for _ in range(3):
            for tag, kw in (("stall", stall_kw), ("sched", chunk_kw)):
                _, eng, _ = _run(cfg, opts, params, mix_reqs, **kw)
                vals[tag].append(float(np.percentile(eng.stats.tick_s, 99)))
                engines[tag] = eng
        p99 = {tag: min(v) for tag, v in vals.items()}
        if p99["sched"] <= P99_RATIO * p99["stall"]:
            break
    for tag, eng in engines.items():
        ph = eng.stats.phase_report()
        emit(f"scheduler/{tag}/tick_p99", p99[tag] * 1e6,
             f"p50={np.percentile(eng.stats.tick_s, 50) * 1e6:.0f}us;"
             f"ticks={len(eng.stats.tick_s)};"
             f"decode_p99={ph.get('decode_tick_p99', 0) * 1e6:.0f}us;"
             f"ttft_mean={np.mean(eng.stats.ttft_s):.4f};"
             f"queue_mean={np.mean(eng.stats.queue_s):.4f}")
    assert p99["sched"] <= P99_RATIO * p99["stall"], \
        f"scheduler p99 tick {p99['sched'] * 1e3:.1f}ms not <= " \
        f"{P99_RATIO}x admit-stall p99 {p99['stall'] * 1e3:.1f}ms " \
        f"(after {attempt + 1} attempts — is the machine saturated?)"
    emit("scheduler/tick_p99_ratio", p99["sched"] / p99["stall"],
         f"gate<={P99_RATIO};stall_p99_ms={p99['stall'] * 1e3:.2f};"
         f"sched_p99_ms={p99['sched'] * 1e3:.2f};attempts={attempt + 1}")
