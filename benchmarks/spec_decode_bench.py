"""Self-speculative decode gates: bit-equal streams, >= 2x tokens per
full-model pass, and clean pool accounting under rejection churn.

Three gates (violations raise — the CI smoke for the speculative tick; see
docs/speculative.md for the design and docs/benchmarks.md for how to read
the output):

1. **Bit-equality.** Speculative greedy streams must be identical to the
   plain fused engine on the same cache layout for every K in {2, 4, 8}
   across (dense, bf16), (paged, bf16) and (paged, int8) — speculation is
   an execution strategy, never a sampling change. The shallow 1-layer
   draft used here accepts rarely, so the reject/rollback path is what is
   actually being exercised.
2. **Tokens per full-model pass.** With the high-acceptance draft the
   design centers on (full-depth, int8 fake-quantized weights — the
   1-byte-weight draft stream), the decode-microbench workload must emit
   >= 2x tokens per full-model HBM pass (accepted-per-verify-pass >= 2.0
   at K=4), with streams still bit-equal to the non-speculative engine.
   Tokens per *pass* is the HBM-traffic proxy the paper's memory-bound
   decode phase cares about; wall-clock is reported, not gated.
3. **Pool accounting under rejection.** After a speculative run on the
   quantized paged pool (every verify pass up to K-1 rejected rows), the
   pool must drain to zero pages in use and accept a second identical
   round with identical output — and at the component level, a fully
   masked chunk write (``n_valid=0``) must leave a fresh pool bit-zero:
   masked rows land on the null page as zeros, never on a real page.

Reported (not gated): accepted-per-pass histograms, the draft/verify phase
split in full-model-pass equivalents, tokens/s, and the speculative
key-lane ratio. The headline figures are written to
``BENCH_spec_decode.json`` (schema in docs/benchmarks.md) so the perf
trajectory is tracked per-PR; ``perf_compare`` diffs it against the
committed baseline.
"""
from __future__ import annotations

import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.models.layers import ModelOptions, update_cache_paged_chunk
from repro.serving import Request, ServingEngine

DESCRIPTION = ("Self-speculative decode gates: greedy streams bit-equal to "
               "the plain fused engine for K in {2,4,8} x {dense, paged, "
               "int8 pool}, >= 2x tokens per full-model pass with the "
               "full-depth int8-weight draft at K=4, pool pages drained "
               "and null page bit-clean after rejection churn; reports "
               "accept histograms + draft/verify split into "
               "BENCH_spec_decode.json")

ARCH = "smollm-135m"
PAGE_SIZE = 8
MAX_SEQ = 64
N_SLOTS = 2

ACCEPT_GATE = 2.0           # gate 2: accepted tokens per verify pass, K=4

BENCH_PATH = os.path.join(os.environ.get("BENCH_DIR", "."),
                          "BENCH_spec_decode.json")


def _run(cfg, opts, params, reqs, *, paged=False, kv_dtype="bf16", **kw):
    eng = ServingEngine(cfg, opts, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                        eos=-999, fused=True, tick_tokens=4, paged=paged,
                        page_size=PAGE_SIZE, kv_dtype=kv_dtype, **kw)
    for i, (p, m) in enumerate(reqs):
        eng.submit(Request(uid=i, prompt=p.copy(), max_tokens=m))
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    assert len(done) == len(reqs), "engine dropped requests"
    return {r.uid: r.out_tokens for r in done}, eng, wall


def _gate_bit_equality(cfg, opts, params, emit):
    rng = np.random.default_rng(0)
    reqs = [(rng.integers(0, cfg.vocab_size, int(rng.integers(6, 15)),
                          dtype=np.int32), int(rng.integers(5, 12)))
            for _ in range(4)]
    for mode, paged, kv_dtype in (("dense", False, "bf16"),
                                  ("paged", True, "bf16"),
                                  ("int8", True, "int8")):
        # the int8 reference must share the speculative engines' per-token
        # scale layout: bit-equality is a same-layout contract
        gran = {"scale_granularity": "token"} if kv_dtype == "int8" else {}
        ref, _, _ = _run(cfg, opts, params, reqs, paged=paged,
                         kv_dtype=kv_dtype, **gran)
        for K in (2, 4, 8):
            got, eng, wall = _run(cfg, opts, params, reqs, paged=paged,
                                  kv_dtype=kv_dtype, spec_decode=True,
                                  spec_k=K, draft_layers=1)
            assert got == ref, \
                f"spec stream diverged from fused ({mode}, K={K})"
            ph = eng.stats.phase_report()
            emit(f"spec_decode/{mode}/k{K}/accept_per_pass",
                 ph["spec_accept_per_pass"],
                 f"hist={ph['spec_accept_hist']};"
                 f"verify_passes={eng.stats.spec_verify_passes};"
                 f"bit_equal=True")
    emit("spec_decode/bit_equal", 1.0,
         "layouts=dense,paged,int8;k=2,4,8;streams_match=True")


def _gate_tokens_per_pass(cfg, opts, params, emit):
    # the decode-microbench workload shape: long prompts, decode-dominated
    rng = np.random.default_rng(1)
    reqs = [(rng.integers(0, cfg.vocab_size, 32, dtype=np.int32), 16)
            for _ in range(4)]
    ref, _, wall_ref = _run(cfg, opts, params, reqs)
    got, eng, wall = _run(cfg, opts, params, reqs, spec_decode=True,
                          spec_k=4, draft_layers=cfg.num_layers,
                          draft_quant="int8")
    assert got == ref, "full-depth int8-draft spec stream diverged"
    ph = eng.stats.phase_report()
    app = ph["spec_accept_per_pass"]
    n_tok = sum(len(v) for v in got.values())
    emit("spec_decode/int8_draft/accept_per_pass", app,
         f"gate>={ACCEPT_GATE};k=4;draft_layers={eng.draft_layers};"
         f"hist={ph['spec_accept_hist']}")
    emit("spec_decode/int8_draft/draft_split", ph["spec_draft_frac"],
         f"draft_pass_equiv={ph['spec_draft_pass_equiv']:.2f};"
         f"verify_passes={eng.stats.spec_verify_passes}")
    emit("spec_decode/int8_draft/decode", wall / n_tok * 1e6,
         f"tok_s={n_tok / wall:.1f};nonspec_tok_s={n_tok / wall_ref:.1f};"
         f"reported_not_gated=True")
    assert app >= ACCEPT_GATE, \
        (f"full-depth int8 draft accepted only {app:.2f} tokens per "
         f"full-model pass (< {ACCEPT_GATE}) — speculation is not paying "
         f"for its verify chunks")
    return ph, app, n_tok


def _gate_pool_accounting(cfg, opts, params, emit):
    # engine level: rejection churn (shallow draft) must drain cleanly and
    # leave full capacity behind
    rng = np.random.default_rng(2)
    reqs = [(rng.integers(0, cfg.vocab_size, int(rng.integers(6, 15)),
                          dtype=np.int32), int(rng.integers(5, 12)))
            for _ in range(5)]
    got, eng, _ = _run(cfg, opts, params, reqs, paged=True, kv_dtype="int8",
                       spec_decode=True, spec_k=4, draft_layers=1)
    assert eng.pool.pages_in_use == 0, \
        f"{eng.pool.pages_in_use} pool pages leaked after speculative drain"
    for i, (p, m) in enumerate(reqs):
        eng.submit(Request(uid=100 + i, prompt=p.copy(), max_tokens=m))
    done = {r.uid - 100: r.out_tokens for r in eng.run() if r.uid >= 100}
    assert done == got, "second round on the drained engine diverged"
    assert eng.pool.pages_in_use == 0, "second-round drain leaked pages"
    emit("spec_decode/pool/drained", 0.0,
         f"pages_hwm={eng.stats.pages_hwm};rounds=2;leaked=0")

    # component level: a fully masked chunk write (the shape of every
    # rejected draft row) must leave a fresh quantized pool bit-zero —
    # masked rows are routed to the null page as zeros, and the null
    # page's codes and scales stay zero
    K, h = cfg.num_kv_heads, cfg.head_dim
    pages = jnp.zeros((4, PAGE_SIZE, K, h), jnp.int8)
    scales = jnp.zeros((4, K), jnp.float32)
    pt = jnp.asarray([[1, 2]], jnp.int32)
    rows = jax.random.normal(jax.random.PRNGKey(0), (1, PAGE_SIZE, K, h))
    p2, s2 = update_cache_paged_chunk(pages, rows, pt, 0, n_valid=0,
                                      scales=scales)
    assert not int(jnp.abs(p2.astype(jnp.int32)).sum()), \
        "masked chunk write left nonzero codes in the pool"
    assert not float(jnp.abs(s2).sum()), \
        "masked chunk write perturbed pool scales"
    # sanity: the same write with valid rows does land on the real pages
    p3, s3 = update_cache_paged_chunk(pages, rows, pt, 0,
                                      n_valid=PAGE_SIZE, scales=scales)
    assert int(jnp.abs(p3[1].astype(jnp.int32)).sum()) > 0
    assert not int(jnp.abs(p3[0].astype(jnp.int32)).sum()), \
        "valid chunk write polluted the null page"
    assert not float(jnp.abs(s3[0]).sum())
    # same contract under per-token scales (the speculative pool layout)
    st = jnp.zeros((4, PAGE_SIZE, K), jnp.float32)
    p4, s4 = update_cache_paged_chunk(pages, rows, pt, 0, n_valid=0,
                                      scales=st)
    assert not int(jnp.abs(p4.astype(jnp.int32)).sum()), \
        "masked per-token chunk write left nonzero codes"
    assert not float(jnp.abs(s4).sum()), \
        "masked per-token chunk write perturbed scales"
    emit("spec_decode/pool/null_page_clean", 1.0,
         "masked_write=all_zero;valid_write=real_pages_only;"
         "granularities=head,token")


def run(emit):
    cfg = get_config(ARCH).reduced()
    opts = ModelOptions(remat=False)
    params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0),
                           jnp.float32)

    _gate_bit_equality(cfg, opts, params, emit)
    ph, app, n_tok = _gate_tokens_per_pass(cfg, opts, params, emit)
    _gate_pool_accounting(cfg, opts, params, emit)

    report = {
        "bench": "spec_decode",
        "schema": 1,
        "spec_k": 4,
        "draft_layers": 4,
        "draft_quant": "int8",
        "accept_per_pass": app,
        "accept_hist": ph["spec_accept_hist"],
        "draft_frac": ph["spec_draft_frac"],
        "draft_pass_equiv": ph["spec_draft_pass_equiv"],
        "spec_key_lane_ratio": ph.get("spec_key_lane_ratio", 1.0),
        "tokens": n_tok,
    }
    with open(BENCH_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("spec_decode/bench_json", 1.0, f"path={BENCH_PATH};schema=1")
