"""§Perf: baseline-vs-optimized comparison for every tagged hillclimb
artifact (artifacts/dryrun/*-<tag>.json vs the untagged baseline), plus
BENCH_*.json trajectory diffs against the committed baselines in
``benchmarks/baselines/`` (see docs/benchmarks.md for the schema).

Both halves are *reported, never gated*: wall-clock figures move with the
machine, so the ledger exists to make drift visible in the bench output
and the uploaded CI artifacts, not to fail a quiet runner for being slower
than the box that committed the baseline."""
from __future__ import annotations

DESCRIPTION = ("Perf regression ledger: roofline deltas for every tagged "
               "hillclimb artifact, plus BENCH_*.json diffs against the "
               "committed baselines in benchmarks/baselines/ "
               "(reported, not gated)")

import json
import os

ART = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")
BENCH_DIR = os.environ.get("BENCH_DIR", ".")
BASELINES = os.path.join(os.path.dirname(__file__), "baselines")


def _key(row):
    return (row["arch"], row["shape"], row["mesh"])


def _compare_bench_json(emit):
    """Diff every BENCH_*.json in ``BENCH_DIR`` against the same-named
    committed baseline, field by numeric field. Missing artifacts or
    baselines are skipped silently — a bench that didn't run this session
    has nothing to compare, and a bench without a committed baseline is
    simply not tracked yet."""
    if not os.path.isdir(BASELINES):
        return
    for fname in sorted(os.listdir(BASELINES)):
        if not (fname.startswith("BENCH_") and fname.endswith(".json")):
            continue
        cur_path = os.path.join(BENCH_DIR, fname)
        if not os.path.isfile(cur_path):
            continue
        with open(os.path.join(BASELINES, fname)) as f:
            ref = json.load(f)
        with open(cur_path) as f:
            cur = json.load(f)
        bench = cur.get("bench", fname[len("BENCH_"):-len(".json")])
        if cur.get("schema") != ref.get("schema"):
            emit(f"perf/bench/{bench}/schema", float(cur.get("schema", 0)),
                 f"baseline_schema={ref.get('schema')};regenerate baseline")
            continue
        for field in sorted(ref):
            if field == "schema":
                continue
            rv, cv = ref[field], cur.get(field)
            if isinstance(rv, bool) or not isinstance(rv, (int, float)):
                continue
            if not isinstance(cv, (int, float)) or isinstance(cv, bool):
                continue
            ratio = cv / rv if rv else 0.0
            emit(f"perf/bench/{bench}/{field}", float(cv),
                 f"baseline={rv:.6g};ratio={ratio:.3f};"
                 f"reported_not_gated=True")


def run(emit):
    _compare_bench_json(emit)
    if not os.path.isdir(ART):
        emit("perf/missing", 0.0, "run repro.launch.sweep first")
        return
    base, tagged = {}, []
    for f in sorted(os.listdir(ART)):
        if not f.endswith(".json") or f.startswith("_"):
            continue
        row = json.load(open(os.path.join(ART, f)))
        if "skipped" in row:
            continue
        if row.get("tag"):
            tagged.append(row)
        else:
            base[_key(row)] = row
    for row in tagged:
        b = base.get(_key(row))
        if b is None:
            continue
        a, ab = row.get("analytic", {}), b.get("analytic", {})
        for metric, cur, ref in [
            ("bound_s",
             max(a.get("flops_per_dev", 0) / 197e12,
                 a.get("hbm_bytes_per_dev", 0) / 819e9,
                 a.get("coll_bytes_per_dev", 0) / 50e9),
             max(ab.get("flops_per_dev", 0) / 197e12,
                 ab.get("hbm_bytes_per_dev", 0) / 819e9,
                 ab.get("coll_bytes_per_dev", 0) / 50e9)),
            ("hlo_flops", row["cost"].get("flops", 0),
             b["cost"].get("flops", 0)),
            ("hlo_coll", row["collectives"].get("total", 0),
             b["collectives"].get("total", 0)),
            ("temp_bytes", row["memory"].get("temp_size_in_bytes", 0),
             b["memory"].get("temp_size_in_bytes", 0)),
        ]:
            gain = ref / cur if cur else 0.0
            emit(f"perf/{row['arch']}/{row['shape']}/{row['tag']}/{metric}",
                 cur * 1e6 if metric == "bound_s" else cur,
                 f"baseline={ref:.3e},gain={gain:.2f}x")
