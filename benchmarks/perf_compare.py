"""§Perf: baseline-vs-optimized comparison for every tagged hillclimb
artifact (artifacts/dryrun/*-<tag>.json vs the untagged baseline)."""
from __future__ import annotations

DESCRIPTION = ("Baseline-vs-optimized roofline deltas for every tagged "
               "hillclimb artifact (perf regression ledger)")

import json
import os

ART = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def _key(row):
    return (row["arch"], row["shape"], row["mesh"])


def run(emit):
    if not os.path.isdir(ART):
        emit("perf/missing", 0.0, "run repro.launch.sweep first")
        return
    base, tagged = {}, []
    for f in sorted(os.listdir(ART)):
        if not f.endswith(".json") or f.startswith("_"):
            continue
        row = json.load(open(os.path.join(ART, f)))
        if "skipped" in row:
            continue
        if row.get("tag"):
            tagged.append(row)
        else:
            base[_key(row)] = row
    for row in tagged:
        b = base.get(_key(row))
        if b is None:
            continue
        a, ab = row.get("analytic", {}), b.get("analytic", {})
        for metric, cur, ref in [
            ("bound_s",
             max(a.get("flops_per_dev", 0) / 197e12,
                 a.get("hbm_bytes_per_dev", 0) / 819e9,
                 a.get("coll_bytes_per_dev", 0) / 50e9),
             max(ab.get("flops_per_dev", 0) / 197e12,
                 ab.get("hbm_bytes_per_dev", 0) / 819e9,
                 ab.get("coll_bytes_per_dev", 0) / 50e9)),
            ("hlo_flops", row["cost"].get("flops", 0),
             b["cost"].get("flops", 0)),
            ("hlo_coll", row["collectives"].get("total", 0),
             b["collectives"].get("total", 0)),
            ("temp_bytes", row["memory"].get("temp_size_in_bytes", 0),
             b["memory"].get("temp_size_in_bytes", 0)),
        ]:
            gain = ref / cur if cur else 0.0
            emit(f"perf/{row['arch']}/{row['shape']}/{row['tag']}/{metric}",
                 cur * 1e6 if metric == "bound_s" else cur,
                 f"baseline={ref:.3e},gain={gain:.2f}x")
