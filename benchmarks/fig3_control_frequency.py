"""Paper Figure 3: control frequency vs model scale (7B..100B) across the
Table-1 edge systems. Asserts the paper's conclusion: bandwidth (GDDR7/PIM)
raises frequency but no configuration reaches 10 Hz at 100B."""
from __future__ import annotations

DESCRIPTION = ("Paper Fig. 3: control frequency vs model scale (7B-100B) "
               "across Table-1 edge systems; gates that no configuration "
               "reaches 10 Hz at 100B")

from repro.core.hardware import TABLE1, get_hardware
from repro.core.scaling import scaling_sweep
from repro.core.xpu_sim import simulate_vla

SIZES = (7e9, 14e9, 30e9, 50e9, 70e9, 100e9)


def run(emit):
    cfgs = scaling_sweep(SIZES)
    best_100b = 0.0
    for cfg, size in zip(cfgs, SIZES):
        for hw_name in TABLE1:
            r = simulate_vla(cfg, get_hardware(hw_name))
            emit(f"fig3/{hw_name}/{size/1e9:.0f}B", r.control_freq_hz * 1e6,
                 f"{r.control_freq_hz:.4f}Hz")
            if size == 100e9:
                best_100b = max(best_100b, r.control_freq_hz)
    emit("fig3/best_100b_freq", best_100b * 1e6,
         f"{best_100b:.3f}Hz_below_10Hz_target={best_100b < 10.0}")
