"""Paper Table 1: hardware catalog echo + derived ridge points and the
per-platform single-stream decode bound for MolmoAct-7B."""
from __future__ import annotations

DESCRIPTION = ("Paper Table 1: hardware catalog echo, derived ridge points, "
               "and the per-platform single-stream decode bound")

from repro.configs import get_config
from repro.core.hardware import CATALOG, TABLE1, get_hardware
from repro.core.xpu_sim import simulate_vla


def run(emit):
    cfg = get_config("molmoact-7b")
    n_bytes = cfg.param_counts()["active"] * 2
    for name in TABLE1:
        hw = get_hardware(name)
        emit(f"table1/{name}/bw_gbs", hw.mem_bw_gbs, f"tflops={hw.total_tflops}")
        emit(f"table1/{name}/ridge_flops_per_byte",
             hw.ridge_flops_per_byte, "compute/bw")
        # analytic per-token decode floor: stream active params once
        floor = n_bytes / (max(hw.pim_bw_gbs, hw.mem_bw_gbs) * 1e9)
        emit(f"table1/{name}/decode_floor_ms_per_tok", floor * 1e3,
             f"{1.0/ (floor * (cfg.n_cot_tokens + 48)):.2f}Hz_ceiling")
