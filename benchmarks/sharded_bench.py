"""Sharded-serving gates: bit-equal streams on a model=N mesh, per-shard
cache bytes at the projected 1/N slice, and a mesh-blind host scheduler.

Three gates (violations raise — the CI smoke for the shard_map-ped serving
engine; see docs/architecture.md §Sharded serving for the design):

1. **Bit-equality.** Greedy streams from ``ServingEngine(mesh=model:N)``
   must be identical to the single-device engine for N in {2, 4, 8}
   across (dense | paged) x (bf16 | int8 pool) x (chunked prefill |
   speculative decode) — sharding is an execution strategy, never a
   sampling change. The reduced config's 4 query / 2 KV heads shard at
   N=2 and hit the GQA-atomic replication fallback at N=4 and N=8, so
   both the partitioned and the replicated cache paths are exercised.
2. **Per-shard cache bytes.** On every paged sharded run the engine's
   measured ``cache_bytes_hwm_shard`` must not exceed the per-device
   figure projected by ``roofline.report.serving_projection`` from the
   same serving-rule table, plus one page of slack — i.e. exactly
   ``total / N`` when heads shard and ``total`` under the replication
   fallback. The accounting is measured from real shard buffers
   (``addressable_shards``), so a silent replication regression fails
   here rather than flattering the projection.
3. **Mesh-blind host policy.** ``serving/scheduler.py`` and
   ``serving/kv_pool.py`` must contain zero mesh- or shard-aware
   identifiers (AST scan of names, attributes, args and imports —
   docstrings may mention the concept). Admission, eviction, paging and
   SLO policy run on page *indices*; the mesh only ever decides how the
   arrays behind those indices are laid out.

Reported (not gated): tokens/s per (mode, N) and the projected
bandwidth-bound tick floor per device. Headline figures land in
``BENCH_sharded.json`` (schema in docs/benchmarks.md); ``perf_compare``
diffs them against the committed baseline. Needs >= 2 visible devices —
the module requests 8 host-platform CPU devices before jax's backend
initializes, and skips cleanly if another module got there first.
"""
from __future__ import annotations

import ast
import json
import os

# must land before the first jax backend touch; harmless if another bench
# already initialized the backend (run() skips when devices stay short)
if "xla_force_host_platform_device_count" not in os.environ.get(
        "XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = ("--xla_force_host_platform_device_count=8 "
                               + os.environ.get("XLA_FLAGS", ""))

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.distributed.sharding import serving_rules
from repro.launch.mesh import make_serving_mesh
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.roofline.report import serving_projection
from repro.serving import Request, ServingEngine

DESCRIPTION = ("Sharded serving gates: greedy streams bit-equal "
               "single-device vs model=N mesh for N in {2,4,8} x "
               "{dense,paged} x {bf16,int8} x {chunked,spec_decode} "
               "(incl. the GQA-atomic replication fallback), per-shard "
               "cache_bytes_hwm at the serving_projection 1/N slice + one "
               "page slack, and an AST scan proving scheduler/kv_pool stay "
               "mesh-blind; writes BENCH_sharded.json")

ARCH = "smollm-135m"
MAX_SEQ = 64
PAGE_SIZE = 8
N_SLOTS = 2
N_REQS = 4
MAX_TOKENS = 8
MESH_SIZES = (2, 4, 8)

# every valid cell of {dense, paged} x {bf16, int8} x {chunked, spec};
# int8 pools require --paged, so the dense/int8 column is empty by
# construction. "paged" rides along as the plain-tick flagship.
_CHUNK = dict(chunked_prefill=True, chunk_size=PAGE_SIZE, token_budget=32)
_SPEC = dict(spec_decode=True, spec_k=3)
MODES = {
    "paged": dict(paged=True, page_size=PAGE_SIZE),
    "dense_chunked": dict(**_CHUNK),
    "dense_spec": dict(**_SPEC),
    "paged_chunked": dict(paged=True, page_size=PAGE_SIZE, **_CHUNK),
    "paged_spec": dict(paged=True, page_size=PAGE_SIZE, **_SPEC),
    "int8_chunked": dict(paged=True, page_size=PAGE_SIZE, kv_dtype="int8",
                         **_CHUNK),
    "int8_spec": dict(paged=True, page_size=PAGE_SIZE, kv_dtype="int8",
                      **_SPEC),
}

# host-side policy files the mesh must never leak into (gate 3)
MESH_BLIND_FILES = ("src/repro/serving/scheduler.py",
                    "src/repro/serving/kv_pool.py")

BENCH_PATH = os.path.join(os.environ.get("BENCH_DIR", "."),
                          "BENCH_sharded.json")


def _requests(cfg):
    rng = np.random.default_rng(0)
    return [rng.integers(2, cfg.vocab_size // 2,
                         size=int(rng.integers(5, 24))).astype(np.int32)
            for _ in range(N_REQS)]


def _run(cfg, opts, params, prompts, mesh=None, **kw):
    eng = ServingEngine(cfg, opts, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                        eos=-999, fused=True, tick_tokens=4, mesh=mesh, **kw)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p.copy(), max_tokens=MAX_TOKENS))
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    assert len(done) == len(prompts), "engine dropped requests"
    return {r.uid: r.out_tokens for r in done}, eng, wall


def _code_identifiers(path):
    """Every identifier the module's *code* mentions — names, attributes,
    call/def args, imports. Docstrings and comments are not code."""
    with open(path) as f:
        tree = ast.parse(f.read(), filename=path)
    idents = set()
    for node in ast.walk(tree):
        for field in ("id", "attr", "name", "arg", "module", "asname"):
            v = getattr(node, field, None)
            if isinstance(v, str):
                idents.add(v)
    return idents


def _gate_mesh_blind(emit):
    for rel in MESH_BLIND_FILES:
        path = os.path.join(os.path.dirname(__file__), os.pardir, rel)
        bad = sorted(i for i in _code_identifiers(path)
                     if "mesh" in i.lower() or "shard" in i.lower())
        assert not bad, (f"{rel} must stay mesh-blind but mentions "
                         f"{bad} — sharding belongs to the engine's "
                         f"device stages, never to host policy")
        emit(f"sharded/mesh_blind/{os.path.basename(rel)}", 0.0, "clean")


def run(emit) -> None:
    _gate_mesh_blind(emit)
    if jax.device_count() < 2:
        emit("sharded/skipped", 0.0,
             f"needs >=2 devices, have {jax.device_count()}; set XLA_FLAGS="
             "--xla_force_host_platform_device_count=8")
        return
    sizes = tuple(n for n in MESH_SIZES if n <= jax.device_count())

    cfg = get_config(ARCH).reduced()
    opts = ModelOptions(remat=False)
    params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0))
    prompts = _requests(cfg)

    headline = {}
    for mode, kw in MODES.items():
        ref, _, _ = _run(cfg, opts, params, prompts, **kw)
        for n in sizes:
            got, eng, wall = _run(cfg, opts, params, prompts,
                                  mesh=make_serving_mesh(n), **kw)
            assert got == ref, (
                f"sharded greedy stream diverged: mode={mode} model={n}")
            st = eng.stats
            rules = serving_rules(n, cfg.num_heads, cfg.num_kv_heads)
            sharded = rules["kv_heads"] is not None
            if kw.get("paged"):
                # gate 2: measured per-shard bytes vs the rule-table
                # projection, one page of slack for allocator rounding
                proj = serving_projection(cfg, n, st.cache_bytes_hwm)
                assert proj.heads_sharded == sharded
                slack = eng._bytes_per_page_shard
                assert st.cache_bytes_hwm_shard <= (
                    proj.cache_bytes_per_dev + slack), (
                    f"mode={mode} model={n}: per-shard HWM "
                    f"{st.cache_bytes_hwm_shard} exceeds projected "
                    f"{proj.cache_bytes_per_dev} + page {slack}")
            toks = sum(len(v) for v in ref.values())
            emit(f"sharded/{mode}/model{n}", wall / toks * 1e6,
                 f"tok_s={toks / wall:.1f};"
                 f"{'heads_sharded' if sharded else 'replicated'};"
                 f"shard_hwm={st.cache_bytes_hwm_shard}")
            headline[f"{mode}_model{n}_tok_s"] = round(toks / wall, 2)

    proj2 = serving_projection(cfg, 2, 0.0)
    report = {"schema": 1, "bench": "sharded", "arch": ARCH,
              "mesh_sizes": list(sizes), "modes": sorted(MODES),
              "t_tick_proj_model2_us": round(proj2.t_tick_s * 1e6, 4),
              **headline}
    with open(BENCH_PATH, "w") as f:
        json.dump(report, f, indent=2, sort_keys=True)
        f.write("\n")
    emit("sharded/bench_json", 1.0, f"path={BENCH_PATH};schema=1")
