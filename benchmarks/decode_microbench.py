"""Measured (wall-clock) decode/prefill/train microbenchmarks on the CPU
container with reduced configs — sanity numbers for the harness itself, and
the phase-latency decomposition measured (not simulated) end to end."""
from __future__ import annotations

DESCRIPTION = ("Measured wall-clock decode/prefill/train microbenchmarks on "
               "reduced configs — the harness sanity numbers, not simulation")

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import model as M
from repro.models.layers import ModelOptions


def _time(fn, *args, n=20):
    fn(*args)  # compile
    t0 = time.perf_counter()
    for _ in range(n):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / n


def run(emit):
    opts = ModelOptions(remat=False)
    for arch in ("smollm-135m", "granite-moe-3b-a800m", "mamba2-780m"):
        cfg = get_config(arch).reduced()
        params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0),
                               jnp.float32)
        B, S = 2, 32
        tok = jnp.ones((B, S), jnp.int32)
        _, caches = M.prefill(cfg, opts, params, {"tokens": tok}, 64,
                              cache_dtype=jnp.float32)
        one = jnp.ones((B, 1), jnp.int32)
        decode = jax.jit(lambda p, t, c, i: M.decode_step(cfg, opts, p, t, c, i))
        t = _time(decode, params, one, caches, S)
        emit(f"micro/{arch}/decode_step", t * 1e6, f"B={B}")
        prefill = jax.jit(lambda p, b: M.prefill(cfg, opts, p, b, 64,
                                                 cache_dtype=jnp.float32))
        t = _time(prefill, params, {"tokens": tok}, n=5)
        emit(f"micro/{arch}/prefill_{S}", t * 1e6, f"B={B}")
