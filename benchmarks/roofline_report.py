"""§Roofline: the three roofline terms per (arch x shape x mesh) from the
dry-run artifacts (artifacts/dryrun/*.json)."""
from __future__ import annotations

DESCRIPTION = ("Roofline decomposition per (arch x shape x mesh) from the "
               "dry-run HLO artifacts under artifacts/dryrun/")

import os

from repro.roofline import load_artifacts, markdown_table, to_terms

ART = os.environ.get("DRYRUN_DIR", "artifacts/dryrun")


def run(emit):
    if not os.path.isdir(ART):
        emit("roofline/missing", 0.0, "run repro.launch.sweep first")
        return
    rows = [r for r in load_artifacts(ART)
            if "skipped" not in r and not r.get("tag")]
    terms = [to_terms(r) for r in rows]
    for t in terms:
        emit(f"roofline/{t.arch}/{t.shape}/{t.mesh}", t.bound_time * 1e6,
             f"dom={t.dominant},frac={t.roofline_fraction:.3f},"
             f"useful={t.useful_flops_ratio:.2f}")
    if terms:
        md = markdown_table(terms)
        out = os.path.join(ART, "..", "roofline_table.md")
        with open(out, "w") as f:
            f.write(md + "\n")
        emit("roofline/table_rows", float(len(terms)), out)
