"""Paged vs dense KV cache: decode throughput, cache memory, prefix sharing.

Three gates (violations raise, so this doubles as the CI smoke for the
paged-KV subsystem):

1. **Bit-equality.** Paged decode (page pool + per-slot page tables) must
   emit token streams bit-identical to the dense reference layout under
   greedy sampling, on both the fused and per-token engine paths.
2. **Memory proportionality.** Per-request cache memory under paging must
   scale with pages actually used (ceil(len/page_size) pages), not with the
   ``max_seq`` each dense slot over-allocates.
3. **Prefix caching.** Repeated prompts (the serving pattern for repeated
   robot observations) must hit the pool's prefix cache, and shared pages
   must be counted in ``EngineStats.prefix_hits``.

Reported rows: tokens/s for both layouts, per-request cache bytes, pool
high-water marks.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.serving import Request, ServingEngine

ARCH = "smollm-135m"
PAGE_SIZE = 8
MAX_SEQ = 64
N_SLOTS = 2


def _run_engine(cfg, opts, params, reqs, *, paged, fused=True):
    eng = ServingEngine(cfg, opts, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                        eos=-999, fused=fused, tick_tokens=4,
                        paged=paged, page_size=PAGE_SIZE)
    for i, (p, m) in enumerate(reqs):
        eng.submit(Request(uid=i, prompt=p.copy(), max_tokens=m))
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    assert len(done) == len(reqs), "engine dropped requests"
    return {r.uid: r.out_tokens for r in done}, done, eng, wall


def run(emit):
    cfg = get_config(ARCH).reduced()
    opts = ModelOptions(remat=False)
    params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    rng = np.random.default_rng(0)

    # mixed lengths/budgets + one repeated observation (prefix-cache target)
    shared = rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)
    reqs = [(shared, 6),
            (rng.integers(0, cfg.vocab_size, 9, dtype=np.int32), 8),
            (shared, 4),
            (rng.integers(0, cfg.vocab_size, 5, dtype=np.int32), 10),
            (shared, 7)]

    results = {}
    for mode, paged in (("dense", False), ("paged", True)):
        toks, done, eng, wall = _run_engine(cfg, opts, params, reqs,
                                            paged=paged)
        n_tok = sum(len(v) for v in toks.values())
        results[mode] = (toks, done, eng)
        emit(f"kv_cache/{mode}/decode", wall / n_tok * 1e6,
             f"tok_s={n_tok / wall:.1f};decode_syncs={eng.stats.decode_syncs}")

    # -- gate 1: bit-equality under greedy sampling ------------------------
    assert results["paged"][0] == results["dense"][0], \
        "paged decode diverged from the dense reference layout"
    ref_toks, _, _, _ = _run_engine(cfg, opts, params, reqs, paged=True,
                                    fused=False)
    assert ref_toks == results["dense"][0], \
        "per-token paged decode diverged from the dense reference layout"
    emit("kv_cache/paged/bit_equal", 1.0, "greedy_streams_match=True")

    # -- gate 2: per-request cache memory ~ pages used, not max_seq --------
    _, done_p, eng_p = results["paged"]
    bpp = eng_p._bytes_per_page
    dense_req_bytes = bpp * (MAX_SEQ // PAGE_SIZE)   # every slot, always
    for r in sorted(done_p, key=lambda r: r.uid):
        need = -(-(len(reqs[r.uid][0]) + len(r.out_tokens)) // PAGE_SIZE)
        got = r.pages_used
        assert 0 < got <= need + 1, \
            f"req {r.uid}: {got} pages held for {need} pages of tokens"
        emit(f"kv_cache/paged/req{r.uid}_bytes", float(got * bpp),
             f"pages={got};shared={r.pages_shared};"
             f"dense_bytes={dense_req_bytes}")
        assert got * bpp < dense_req_bytes, \
            f"req {r.uid}: paged cache not smaller than dense max_seq"
    emit("kv_cache/paged/pool_hwm_bytes", float(eng_p.stats.cache_bytes_hwm),
         f"pages_hwm={eng_p.stats.pages_hwm};"
         f"dense_total={bpp * N_SLOTS * (MAX_SEQ // PAGE_SIZE)}")

    # -- gate 3: prefix cache hits for the repeated observation ------------
    hits = eng_p.stats.prefix_hits
    assert hits >= 2 * (len(shared) // PAGE_SIZE), \
        f"repeated prompts produced only {hits} prefix-cache page hits"
    emit("kv_cache/paged/prefix_hits", float(hits),
         f"repeated_prompts=3;full_pages_each={len(shared) // PAGE_SIZE}")
