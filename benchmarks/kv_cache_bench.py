"""Paged vs dense KV cache: decode throughput, cache memory, prefix sharing,
and the quantized (int8/fp8) page-pool gates.

Six gates (violations raise, so this doubles as the CI smoke for the
paged-KV subsystem — see docs/benchmarks.md for how to read the output):

1. **Bit-equality.** Paged decode (page pool + per-slot page tables) must
   emit token streams bit-identical to the dense reference layout under
   greedy sampling, on both the fused and per-token engine paths.
2. **Memory proportionality.** Per-request cache memory under paging must
   scale with pages actually used (ceil(len/page_size) pages), not with the
   ``max_seq`` each dense slot over-allocates.
3. **Prefix caching.** Repeated prompts (the serving pattern for repeated
   robot observations) must hit the pool's prefix cache, and shared pages
   must be counted in ``EngineStats.prefix_hits``.
4. **Quantized greedy agreement.** int8 paged decode must emit greedy token
   streams identical to the bf16 paged engine on this workload (fp8
   agreement is reported but not gated — e4m3's 3-bit mantissa leaves less
   argmax margin, and a cross-platform near-tie must not flake CI).
5. **Quantized memory.** The int8/fp8 pool (1-byte codes + per-page-per-head
   f32 scales) must cost <= 0.55x the *bf16-equivalent* bytes per page (2
   bytes/element, the paper-facing comparison) and <= 0.30x the engine's
   actual f32 oracle pool, on both bytes-per-page and ``cache_bytes_hwm``.
6. **Logit error bound.** Stepwise decode logits of the quantized pool must
   stay within an absolute bound of the bf16 paged logits (int8 tighter
   than fp8), measured over a fresh prefill + decode rollout.

Reported rows: tokens/s per layout/dtype, per-request cache bytes, pool
high-water marks, quantized byte ratios and max logit errors.

Also reported (not gated): a **scale-granularity study** — the stored
prefix KV fake-quantized at per-page, per-(page, head) (the pool's shipped
format) and per-(page, head, token) scale granularity, teacher-forced
against the unquantized rollout. The rows quantify the accuracy/overhead
trade the per-(page, head) choice sits on: finer scales cost f32 sidecar
elements per page, coarser scales couple every head's range to the page's
loudest head.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import kv_quant
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.models.stacks import is_paged_leaf
from repro.serving import Request, ServingEngine
from repro.serving.engine import _scatter_pages, _scatter_slot

DESCRIPTION = ("Paged-vs-dense KV gates: greedy bit-equality, memory ~ pages "
               "used, prefix-cache hits, int8/fp8 quantized-pool agreement + "
               "<=0.55x bf16 bytes + logit error bounds")

ARCH = "smollm-135m"
PAGE_SIZE = 8
MAX_SEQ = 64
N_SLOTS = 2

# absolute logit-error bounds vs the bf16 paged rollout (gate 6); measured
# max errors on this workload are ~0.06 (int8) / ~0.22 (fp8), bounds carry
# ~3x margin so only a real regression (scale mishandling, drift) trips them
INT8_LOGIT_TOL = 0.2
FP8_LOGIT_TOL = 0.75


def _run_engine(cfg, opts, params, reqs, *, paged, fused=True,
                kv_dtype="bf16"):
    eng = ServingEngine(cfg, opts, params, n_slots=N_SLOTS, max_seq=MAX_SEQ,
                        eos=-999, fused=fused, tick_tokens=4,
                        paged=paged, page_size=PAGE_SIZE, kv_dtype=kv_dtype)
    for i, (p, m) in enumerate(reqs):
        eng.submit(Request(uid=i, prompt=p.copy(), max_tokens=m))
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    assert len(done) == len(reqs), "engine dropped requests"
    return {r.uid: r.out_tokens for r in done}, done, eng, wall


def _logit_rollout(cfg, opts, params, prompt, n_steps, kv_dtype,
                   force_tokens=None):
    """Prefill + n_steps decode against a hand-built page table; returns
    (per-step logits [n_steps, V], greedy tokens [n_steps]). Component-level
    (no engine) so the quantized-vs-bf16 comparison is purely about pool
    storage. ``force_tokens`` teacher-forces the fed tokens (pass the bf16
    rollout's greedy tokens) so a near-tie argmax flip in the quantized run
    cannot compound into unrelated downstream logits — the comparison then
    measures pure storage-induced drift at every step."""
    ps, npg = PAGE_SIZE, MAX_SEQ // PAGE_SIZE
    logits, cache1 = M.prefill(cfg, opts, params, {"tokens": prompt[None]},
                               MAX_SEQ, cache_dtype=jnp.float32)
    caches = M.init_caches(cfg, 1, MAX_SEQ, jnp.float32, opts, paged=True,
                           num_pages=npg + 1, page_size=ps,
                           kv_dtype=kv_dtype)
    # identity mapping: logical page i -> physical page i+1 (0 is the null
    # page); prefill pages scattered, decode-growth pages left zeroed
    pt = jnp.arange(1, npg + 1, dtype=jnp.int32)[None]
    dest = np.zeros(npg, np.int32)
    n_prompt_pages = len(prompt) // ps
    dest[:n_prompt_pages] = np.arange(1, n_prompt_pages + 1)
    caches = _scatter_pages(caches, cache1, jnp.asarray(dest), ps)
    caches = _scatter_slot(caches, cache1, 0, skip_paged=True)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out, greedy = [], []
    for i in range(n_steps):
        idx = jnp.asarray([len(prompt) + i], jnp.int32)
        logits, caches = M.decode_step(cfg, opts, params, tok, caches, idx,
                                       page_table=pt)
        out.append(logits[0, -1])
        nxt = int(jnp.argmax(logits[0, -1]))
        greedy.append(nxt)
        tok = jnp.asarray([[nxt if force_tokens is None
                            else force_tokens[i]]], jnp.int32)
    return jnp.stack(out), greedy


def _fake_quant_cache(caches, dtype, reduce_axes):
    """Round-trip every dense KV cache leaf through ``dtype`` codes with
    amax scales at a chosen granularity. Leaves are ``[..., S, K, h]``
    (token, kv-head, head-dim trailing); the token axis is reshaped to
    ``(num_pages, PAGE_SIZE)`` so ``reduce_axes`` — relative to the
    reshaped ``[..., np, ps, K, h]`` — selects the scale granularity:
    ``(-3, -2, -1)`` per-page, ``(-3, -1)`` per-(page, head) (the pool's
    format), ``(-1,)`` per-(page, head, token)."""
    def leaf(x):
        if not jnp.issubdtype(x.dtype, jnp.floating) or x.ndim < 3:
            return x
        S = x.shape[-3]
        y = x.reshape(x.shape[:-3] + (S // PAGE_SIZE, PAGE_SIZE)
                      + x.shape[-2:])
        a = jnp.max(jnp.abs(y.astype(jnp.float32)), axis=reduce_axes,
                    keepdims=True)
        scale = a / kv_quant.qmax(kv_quant.quant_dtype(dtype))
        y = kv_quant.decode(
            kv_quant.encode(y, scale, kv_quant.quant_dtype(dtype)), scale)
        return y.reshape(x.shape).astype(x.dtype)

    return jax.tree_util.tree_map(leaf, caches)


def _dense_rollout(cfg, opts, params, prompt, n_steps, quant=None,
                   force_tokens=None):
    """Prefill + teacher-forced decode on the dense cache layout; ``quant``
    fake-quantizes the prefill cache before decoding, so the logit delta vs
    the unquantized rollout isolates stored-prefix quantization error at the
    chosen granularity (decode-written rows stay full precision)."""
    logits, caches = M.prefill(cfg, opts, params, {"tokens": prompt[None]},
                               MAX_SEQ, cache_dtype=jnp.float32)
    if quant is not None:
        caches = quant(caches)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out, greedy = [], []
    for i in range(n_steps):
        idx = jnp.asarray([len(prompt) + i], jnp.int32)
        logits, caches = M.decode_step(cfg, opts, params, tok, caches, idx)
        out.append(logits[0, -1])
        nxt = int(jnp.argmax(logits[0, -1]))
        greedy.append(nxt)
        tok = jnp.asarray([[nxt if force_tokens is None
                            else force_tokens[i]]], jnp.int32)
    return jnp.stack(out), greedy


# (reduce_axes over [..., np, ps, K, h], f32 scale elements per page-head)
GRANULARITIES = (("per_page", (-3, -2, -1)),
                 ("per_page_head", (-3, -1)),
                 ("per_page_head_token", (-1,)))


def run(emit):
    cfg = get_config(ARCH).reduced()
    opts = ModelOptions(remat=False)
    params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    rng = np.random.default_rng(0)

    # mixed lengths/budgets + one repeated observation (prefix-cache target)
    shared = rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)
    reqs = [(shared, 6),
            (rng.integers(0, cfg.vocab_size, 9, dtype=np.int32), 8),
            (shared, 4),
            (rng.integers(0, cfg.vocab_size, 5, dtype=np.int32), 10),
            (shared, 7)]

    results = {}
    for mode, paged in (("dense", False), ("paged", True)):
        toks, done, eng, wall = _run_engine(cfg, opts, params, reqs,
                                            paged=paged)
        n_tok = sum(len(v) for v in toks.values())
        results[mode] = (toks, done, eng)
        emit(f"kv_cache/{mode}/decode", wall / n_tok * 1e6,
             f"tok_s={n_tok / wall:.1f};decode_syncs={eng.stats.decode_syncs}")

    # -- gate 1: bit-equality under greedy sampling ------------------------
    assert results["paged"][0] == results["dense"][0], \
        "paged decode diverged from the dense reference layout"
    ref_toks, _, _, _ = _run_engine(cfg, opts, params, reqs, paged=True,
                                    fused=False)
    assert ref_toks == results["dense"][0], \
        "per-token paged decode diverged from the dense reference layout"
    emit("kv_cache/paged/bit_equal", 1.0, "greedy_streams_match=True")

    # -- gate 2: per-request cache memory ~ pages used, not max_seq --------
    _, done_p, eng_p = results["paged"]
    bpp = eng_p._bytes_per_page
    dense_req_bytes = bpp * (MAX_SEQ // PAGE_SIZE)   # every slot, always
    for r in sorted(done_p, key=lambda r: r.uid):
        need = -(-(len(reqs[r.uid][0]) + len(r.out_tokens)) // PAGE_SIZE)
        got = r.pages_used
        assert 0 < got <= need + 1, \
            f"req {r.uid}: {got} pages held for {need} pages of tokens"
        emit(f"kv_cache/paged/req{r.uid}_bytes", float(got * bpp),
             f"pages={got};shared={r.pages_shared};"
             f"dense_bytes={dense_req_bytes}")
        assert got * bpp < dense_req_bytes, \
            f"req {r.uid}: paged cache not smaller than dense max_seq"
    emit("kv_cache/paged/pool_hwm_bytes", float(eng_p.stats.cache_bytes_hwm),
         f"pages_hwm={eng_p.stats.pages_hwm};"
         f"dense_total={bpp * N_SLOTS * (MAX_SEQ // PAGE_SIZE)}")

    # -- gate 3: prefix cache hits for the repeated observation ------------
    hits = eng_p.stats.prefix_hits
    assert hits >= 2 * (len(shared) // PAGE_SIZE), \
        f"repeated prompts produced only {hits} prefix-cache page hits"
    emit("kv_cache/paged/prefix_hits", float(hits),
         f"repeated_prompts=3;full_pages_each={len(shared) // PAGE_SIZE}")

    # -- gates 4+5: quantized pool — greedy agreement + memory -------------
    # the engine's unquantized pool stores f32 (the bit-equality oracle);
    # the paper-facing ratio compares against what bf16 storage would cost
    bf16_equiv_bpp = sum(
        leaf.size * 2 // eng_p.pool.num_pages for path, leaf in
        jax.tree_util.tree_leaves_with_path(eng_p.caches)
        if is_paged_leaf(path))
    for kv_dtype in ("int8", "fp8"):
        toks_q, done_q, eng_q, wall_q = _run_engine(
            cfg, opts, params, reqs, paged=True, kv_dtype=kv_dtype)
        n_tok = sum(len(v) for v in toks_q.values())
        match = [u for u in toks_q if toks_q[u] == results["paged"][0][u]]
        emit(f"kv_cache/{kv_dtype}/decode", wall_q / n_tok * 1e6,
             f"tok_s={n_tok / wall_q:.1f};"
             f"streams_matching_bf16={len(match)}/{len(reqs)}")
        if kv_dtype == "int8":
            assert toks_q == results["paged"][0], \
                "int8 paged greedy streams diverged from bf16 paged"
        assert eng_q.stats.prefix_hits == hits, \
            f"{kv_dtype}: quantized pool lost prefix-cache hits"
        bpp_q = eng_q._bytes_per_page
        ratio_bf16 = bpp_q / bf16_equiv_bpp
        ratio_f32 = bpp_q / bpp
        emit(f"kv_cache/{kv_dtype}/bytes_per_page", float(bpp_q),
             f"vs_bf16={ratio_bf16:.3f};vs_f32_oracle={ratio_f32:.3f}")
        assert ratio_bf16 <= 0.55, \
            f"{kv_dtype} pool costs {ratio_bf16:.3f}x bf16 (> 0.55x)"
        assert ratio_f32 <= 0.30, \
            f"{kv_dtype} pool costs {ratio_f32:.3f}x the f32 pool (> 0.30x)"
        assert eng_q.stats.pages_hwm == eng_p.stats.pages_hwm, \
            f"{kv_dtype}: page high-water diverged from bf16 paging"
        hwm_bf16_equiv = eng_p.stats.pages_hwm * bf16_equiv_bpp
        emit(f"kv_cache/{kv_dtype}/pool_hwm_bytes",
             float(eng_q.stats.cache_bytes_hwm),
             f"bf16_equiv_hwm={hwm_bf16_equiv};"
             f"ratio={eng_q.stats.cache_bytes_hwm / hwm_bf16_equiv:.3f}")
        assert eng_q.stats.cache_bytes_hwm <= 0.55 * hwm_bf16_equiv, \
            f"{kv_dtype} cache_bytes_hwm not <= 0.55x the bf16 paged figure"

    # -- gate 6: stepwise logit error vs the bf16 paged rollout ------------
    # teacher-forced on the bf16 greedy tokens: every step feeds the same
    # token to both pools, so the error measures storage drift alone
    prompt = rng.integers(0, cfg.vocab_size, 16, dtype=np.int32)
    ref_logits, ref_greedy = _logit_rollout(cfg, opts, params, prompt, 8,
                                            "bf16")
    for kv_dtype, tol in (("int8", INT8_LOGIT_TOL), ("fp8", FP8_LOGIT_TOL)):
        q_logits, q_greedy = _logit_rollout(cfg, opts, params, prompt, 8,
                                            kv_dtype,
                                            force_tokens=ref_greedy)
        err = float(jnp.max(jnp.abs(q_logits - ref_logits)))
        spread = float(jnp.max(ref_logits) - jnp.min(ref_logits))
        agree = sum(a == b for a, b in zip(ref_greedy, q_greedy))
        emit(f"kv_cache/{kv_dtype}/logit_err", err,
             f"tol={tol};logit_spread={spread:.2f};"
             f"greedy_agree={agree}/{len(ref_greedy)}")
        assert err <= tol, \
            f"{kv_dtype} decode logits drifted {err:.4f} from bf16 (> {tol})"

    # -- scale-granularity study (reported, not gated) ---------------------
    # same teacher-forced protocol as gate 6 but on the dense layout with
    # the prefix KV fake-quantized at three scale granularities; sidecar =
    # f32 scale elements per (page, layer, K/V) — the storage the finer
    # granularity buys its accuracy with (page rows are ps*h elements)
    g_logits, g_greedy = _dense_rollout(cfg, opts, params, prompt, 8)
    n_kv = cfg.num_kv_heads
    sidecar = {"per_page": 1, "per_page_head": n_kv,
               "per_page_head_token": PAGE_SIZE * n_kv}
    for kv_dtype in ("int8", "fp8"):
        for gran, axes in GRANULARITIES:
            q = lambda c: _fake_quant_cache(c, kv_dtype, axes)
            ql, qg = _dense_rollout(cfg, opts, params, prompt, 8, quant=q,
                                    force_tokens=g_greedy)
            err = float(jnp.max(jnp.abs(ql - g_logits)))
            agree = sum(a == b for a, b in zip(g_greedy, qg))
            emit(f"kv_cache/granularity/{kv_dtype}/{gran}", err,
                 f"greedy_agree={agree}/{len(g_greedy)};"
                 f"scale_elems_per_page={sidecar[gran]}")
