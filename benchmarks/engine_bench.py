"""Fused vs per-token reference serving engine: host syncs and tokens/s.

The fused tick (lax.while_loop over up to K decode steps with device-resident
per-slot state) must (a) emit bit-identical greedy token streams and (b) cut
decode-path host syncs from N to <= ceil(N/K) for an N-token decode — the
per-step launch/sync overhead the paper identifies as first-order for the
memory-bound action-generation phase. Violations raise, so the benchmark
doubles as a CI smoke gate for the serving stack.
"""
from __future__ import annotations

DESCRIPTION = ("Fused vs per-token serving engine: gates bit-identical "
               "greedy streams and decode host syncs <= ceil(N/K); reports "
               "tokens/s for both paths")

import math
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import model as M
from repro.models.layers import ModelOptions
from repro.serving import Request, ServingEngine

ARCH = "smollm-135m"
K = 8          # fused tick size
N = 17         # tokens per request (1 prefill + N-1 decode)


def _run_engine(cfg, opts, params, fused, n_slots, prompts, max_tokens):
    eng = ServingEngine(cfg, opts, params, n_slots=n_slots, max_seq=64,
                        eos=-999, fused=fused, tick_tokens=K)
    for i, p in enumerate(prompts):
        eng.submit(Request(uid=i, prompt=p.copy(), max_tokens=max_tokens))
    t0 = time.perf_counter()
    done = eng.run()
    wall = time.perf_counter() - t0
    return {r.uid: r.out_tokens for r in done}, eng.stats, wall


def run(emit):
    cfg = get_config(ARCH).reduced()
    opts = ModelOptions(remat=False)
    params = M.init_params(M.model_template(cfg), jax.random.PRNGKey(0),
                           jnp.float32)
    rng = np.random.default_rng(0)

    # -- single stream: the ceil(N/K) host-sync contract -------------------
    prompt = [rng.integers(0, cfg.vocab_size, 8, dtype=np.int32)]
    results = {}
    for mode, fused in (("ref", False), ("fused", True)):
        toks, st, wall = _run_engine(cfg, opts, params, fused, 1, prompt, N)
        results[mode] = (toks, st)
        n_tok = sum(len(v) for v in toks.values())
        emit(f"engine/{mode}/single_stream", wall / n_tok * 1e6,
             f"tok_s={n_tok / wall:.1f};decode_syncs={st.decode_syncs}")
    ref_toks, ref_st = results["ref"]
    fus_toks, fus_st = results["fused"]
    bound = math.ceil((N - 1) / K)     # N-1 decode steps after prefill
    assert fus_toks == ref_toks, "fused decode diverged from reference"
    assert fus_st.decode_syncs <= bound, \
        f"fused syncs {fus_st.decode_syncs} > ceil(N/K) = {bound}"
    assert ref_st.decode_syncs == N - 1
    emit("engine/fused/sync_bound", float(fus_st.decode_syncs),
         f"bound={bound};ref={ref_st.decode_syncs};match=True")

    # -- continuous batching: mixed lengths, more requests than slots ------
    prompts = [rng.integers(0, cfg.vocab_size, int(l), dtype=np.int32)
               for l in (6, 9, 4, 7)]
    batch = {}
    for mode, fused in (("ref", False), ("fused", True)):
        toks, st, wall = _run_engine(cfg, opts, params, fused, 2, prompts, 12)
        batch[mode] = toks
        n_tok = sum(len(v) for v in toks.values())
        emit(f"engine/{mode}/batched", wall / n_tok * 1e6,
             f"tok_s={n_tok / wall:.1f};decode_syncs={st.decode_syncs};"
             f"device_steps={st.device_steps}")
    assert batch["fused"] == batch["ref"], \
        "fused continuous batching diverged from reference"
