"""Paper Figure 2: MolmoAct-7B phase latency on Jetson Orin and Thor.

Emits per-phase seconds + the headline ratios the paper reports (generation
fraction ~75%, Thor/Orin e2e speedup ~1.4x, 200-300x off the 10 Hz target).
"""
from __future__ import annotations

DESCRIPTION = ("Paper Fig. 2: simulated MolmoAct-7B vision/prefill/decode "
               "phase latency on Jetson Orin + Thor; gates the ~75% "
               "action-generation fraction and Thor/Orin speedup")

from repro.configs import get_config
from repro.core.hardware import ORIN, THOR
from repro.core.xpu_sim import simulate_vla


def run(emit):
    cfg = get_config("molmoact-7b")
    reports = {hw.name: simulate_vla(cfg, hw) for hw in (ORIN, THOR)}
    for name, r in reports.items():
        for phase, secs in r.phase_seconds().items():
            emit(f"fig2/{name}/{phase}", secs * 1e6, f"{secs:.3f}s")
        emit(f"fig2/{name}/e2e", r.e2e * 1e6,
             f"{r.e2e:.2f}s={r.e2e/0.1:.0f}x_off_10Hz")
        emit(f"fig2/{name}/generation_fraction",
             r.generation_fraction * 1e6, f"{r.generation_fraction:.3f}")
    speed = reports["jetson-orin"].e2e / reports["jetson-thor"].e2e
    emit("fig2/thor_speedup", speed * 1e6, f"{speed:.2f}x_vs_5x_compute")
    dec = [p for p in reports["jetson-orin"].phases
           if p.name == "generation_decode"][0]
    emit("fig2/decode_memory_fraction", dec.memory_fraction * 1e6,
         f"{dec.memory_fraction:.3f}_memory_bound")
