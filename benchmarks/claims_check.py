"""Reproduction fidelity: every published claim of the paper, validated."""
from __future__ import annotations

DESCRIPTION = ("Reproduction fidelity: validates every published claim of "
               "the paper and fails on any deviation")

from repro.core.claims import validate_all


def run(emit):
    for c in validate_all():
        emit(f"claims/{c['claim']}", float(c["measured"]) * 1e6,
             f"ok={c['ok']}|{c['expectation']}")
