"""Benchmark harness — one module per paper table/figure plus the roofline
report and measured microbenchmarks. Prints ``name,us_per_call,derived``.

    PYTHONPATH=src python -m benchmarks.run [--only fig2,fig3,...]
    PYTHONPATH=src python -m benchmarks.run --list

Every module carries a ``DESCRIPTION`` (one line: what it measures and what
it gates) surfaced by ``--list`` — the same text docs/benchmarks.md expands
on, so the tool and the docs can't drift apart silently.
"""
from __future__ import annotations

import argparse
import sys
import traceback

from benchmarks import (claims_check, decode_microbench, engine_bench,
                        fig2_phase_latency, fig3_control_frequency,
                        frontend_bench, kv_cache_bench, perf_compare,
                        roofline_report, scheduler_bench, sharded_bench,
                        spec_decode_bench, table1_hardware)

MODULES = {
    "claims": claims_check,
    "fig2": fig2_phase_latency,
    "table1": table1_hardware,
    "fig3": fig3_control_frequency,
    "roofline": roofline_report,
    "perf": perf_compare,
    "micro": decode_microbench,
    "engine": engine_bench,
    "kv_cache": kv_cache_bench,
    "scheduler": scheduler_bench,
    "frontend": frontend_bench,
    "spec_decode": spec_decode_bench,
    "sharded": sharded_bench,
}


def main() -> None:
    p = argparse.ArgumentParser()
    p.add_argument("--only", default="")
    p.add_argument("--list", action="store_true",
                   help="print each benchmark's name and DESCRIPTION, "
                        "then exit")
    args = p.parse_args()
    if args.list:
        width = max(len(k) for k in MODULES)
        for key, mod in MODULES.items():
            desc = getattr(mod, "DESCRIPTION", None) or next(
                iter((mod.__doc__ or "").strip().splitlines()), "")
            print(f"{key:<{width}}  {desc}")
        return
    selected = args.only.split(",") if args.only else list(MODULES)

    rows = []

    def emit(name: str, us_per_call: float, derived: str = ""):
        rows.append((name, us_per_call, derived))
        print(f"{name},{us_per_call:.3f},{derived}", flush=True)

    print("name,us_per_call,derived")
    failed = []
    for key in selected:
        try:
            MODULES[key].run(emit)
        except Exception:
            failed.append(key)
            traceback.print_exc()
    if failed:
        print(f"# FAILED modules: {failed}", file=sys.stderr)
        sys.exit(1)
    print(f"# {len(rows)} rows from {len(selected)} modules")


if __name__ == "__main__":
    main()
